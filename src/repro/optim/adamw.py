"""AdamW with global-norm gradient clipping, implemented directly in JAX
(no optax dependency is available in this container)."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray          # scalar int32
    mu: Any                    # first moment (pytree like params)
    nu: Any                    # second moment


class AdamW(NamedTuple):
    lr: Callable[[jnp.ndarray], jnp.ndarray]   # schedule: step -> lr
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        if self.clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        else:
            gnorm = global_norm(grads)
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2)
                          * jnp.square(g.astype(jnp.float32)),
                          state.nu, grads)
        mu_hat = jax.tree.map(lambda m: m / (1 - b1 ** step), mu)
        nu_hat = jax.tree.map(lambda v: v / (1 - b2 ** step), nu)
        lr = self.lr(step)

        def upd(p, m, v):
            u = m / (jnp.sqrt(v) + self.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu_hat, nu_hat)
        return new_params, AdamWState(step=step, mu=mu, nu=nu), gnorm


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak: float, warmup: int, total: int, floor: float = 0.0):
    def f(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return f

"""Live prefill/decode disaggregation demo (§6.3): the same prompts served
by a colocated engine and by a disaggregated 1P1D data plane — a
prefill-role engine on the compute pool ("H800"), a decode-role engine on
the bandwidth pool ("H20"), and a KV-cache slot handoff in between. At
temperature 0 the two paths emit identical tokens, and the per-pool
counters show prefill tokens landing only on the prefill pool and decode
tokens only on the decode pool.

    PYTHONPATH=src python examples/serve_pd_disagg.py
"""
import argparse

import jax

from repro.configs import get_config
from repro.core import EngineHandle, LLMProxy, build_pd_proxy
from repro.data.tokenizer import TOKENIZER
from repro.models import Model
from repro.rl.engine import GenRequest, InferenceEngine


def serve(proxy, prompts, max_new):
    out = {}
    for i, p in enumerate(prompts):
        proxy.submit(
            GenRequest(request_id=f"r{i}",
                       prompt=TOKENIZER.encode(p, bos=True),
                       max_new_tokens=max_new, temperature=0.0),
            callback=lambda r: out.__setitem__(r.request_id, r))
    while proxy.busy:
        proxy.pump()
    return [out[f"r{i}"].tokens for i in range(len(prompts))]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny")
    ap.add_argument("--max-new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    prompts = ["the agent moves ", "reward comes from ", "decode prefill "]

    colocated = LLMProxy([EngineHandle(
        InferenceEngine(model, params, max_slots=4, max_len=256), "H800")])
    tokens_col = serve(colocated, prompts, args.max_new_tokens)

    pd = build_pd_proxy(model, params, max_slots=4, max_len=256)
    tokens_pd = serve(pd, prompts, args.max_new_tokens)

    for p, tc, tp in zip(prompts, tokens_col, tokens_pd):
        match = "==" if tc == tp else "!="
        print(f"{p!r}: colocated {match} disaggregated | "
              f"{TOKENIZER.decode(tp)!r}")
    assert tokens_col == tokens_pd, "greedy parity violated"

    stats = pd.stats()
    print(f"\nhandoffs: {stats['handoffs']}")
    for e in stats["engines"]:
        print(f"  pool={e['pool']:5s} role={e['role']:7s} "
              f"prefill_tokens={e['prefill_tokens']:4d} "
              f"decode_tokens={e['decode_tokens']:4d} "
              f"steps={e['steps']}")


if __name__ == "__main__":
    main()

"""Agentic RL on a heterogeneous rollout pool with hardware-affinity
workload mapping (paper §5.2): engines acquire device groups through the
ResourceManager (prefill -> compute-class H800, decode -> bandwidth-class
H20), the PerfModel prices each placement, and the dynamic rebalancer
switches an engine's role — releasing and re-binding its device group —
when the prefill/decode queue-depth ratio leaves the hysteresis band.

    PYTHONPATH=src python examples/train_hetero_pools.py --steps 3
"""
import argparse

import jax

from repro.configs import get_config
from repro.core import (LiveRLRunner, RebalancerConfig, ResourceManager,
                        RunnerConfig, ServerlessPlatform, build_pd_proxy,
                        parse_pools)
from repro.core.proxy import format_placement_row, format_switch_event
from repro.models import Model
from repro.rewards.rule_based import REWARD_FNS
from repro.rl.trainer import (default_optimizer, init_train_state,
                              make_grpo_train_step)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--group", type=int, default=2)
    ap.add_argument("--pools", default="H800:2,H20:2")
    ap.add_argument("--mode", default="rollart")
    args = ap.parse_args(argv)

    cfg = get_config("tiny")
    model = Model(cfg, remat=False)
    opt = default_optimizer(1e-3)
    state = init_train_state(model, jax.random.PRNGKey(0), opt)

    rm = ResourceManager(parse_pools(args.pools))
    # deliberately mis-split (2 prefill / 1 decode): watch the rebalancer
    # correct it once the decode side backlogs
    proxy = build_pd_proxy(model, state.params, max_slots=4, max_len=256,
                           n_prefill=2, n_decode=1, resource_manager=rm,
                           rebalancer=RebalancerConfig())
    print("initial placement (PerfModel pricing):")
    for row in proxy.placement_report():
        print("  " + format_placement_row(row))

    with LiveRLRunner(
            RunnerConfig(batch_size=args.batch, group_size=args.group,
                         mode=args.mode, max_new_tokens=16,
                         pd_disagg=True, affinity=True),
            proxy, state, jax.jit(make_grpo_train_step(model, opt)),
            ServerlessPlatform(), REWARD_FNS["format_bonus"],
            seq_len=256) as runner:
        for h in runner.run_steps(args.steps):
            print(f"step {h.step} loss {h.loss:.4f} "
                  f"reward {h.reward_mean:.3f} "
                  f"role_switches {h.role_switches}")
        for ev in runner.proxy.switch_log:
            print(format_switch_event(ev))
        print("final placement:")
        for row in runner.placement_report():
            print("  " + format_placement_row(row))
        print("resource snapshot:", rm.snapshot()["free"])
    proxy.release_bindings()


if __name__ == "__main__":
    main()

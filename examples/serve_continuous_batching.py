"""Serving example: the command-driven continuous-batching engine (paper
Fig. 8) under batched requests — ADD/ABORT between engine steps, affinity
routing across two pools, and a mid-flight weight update with KV-cache
recomputation.

    PYTHONPATH=src python examples/serve_continuous_batching.py
"""
import jax

from repro.configs import get_config
from repro.core import EngineHandle, LLMProxy
from repro.data.tokenizer import TOKENIZER
from repro.models import Model
from repro.rl.engine import GenRequest, InferenceEngine


def main():
    cfg = get_config("tiny")
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    e1 = InferenceEngine(model, params, max_slots=4, max_len=256, seed=1)
    e2 = InferenceEngine(model, params, max_slots=4, max_len=256, seed=2)
    proxy = LLMProxy([EngineHandle(e1, "H800"), EngineHandle(e2, "H20")],
                     hw_affinity={"code": "H800", "chat": "H20",
                                  "default": "H20"})

    done = []
    prompts = [("code", "def add(a, b):"), ("chat", "hello there, "),
               ("code", "for i in range("), ("chat", "the weather is "),
               ("chat", "i think that ")]
    for i, (tag, text) in enumerate(prompts):
        proxy.submit(GenRequest(request_id=f"req{i}",
                                prompt=TOKENIZER.encode(text, bos=True),
                                max_new_tokens=24, temperature=0.9, tag=tag),
                     callback=done.append)

    # interleave: a few engine steps, then abort one request (trajectory-
    # level control), then a weight update mid-flight (protocol steps 2-5)
    for _ in range(4):
        proxy.pump()
    proxy.abort("req2")
    proxy.suspend()
    new_params = model.init(jax.random.PRNGKey(7))
    proxy.update_all(new_params, version=1, recompute_caches=True)
    proxy.resume()
    while proxy.busy:
        proxy.pump()

    for r in done:
        print(f"{r.request_id}: finish={r.finish_reason:7s} "
              f"v{r.weight_version} new_tokens={len(r.tokens)}")
    print("routing:", proxy.stats()["routed_by_pool"])


if __name__ == "__main__":
    main()

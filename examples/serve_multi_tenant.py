"""Rollout-as-a-Service demo: two weighted tenants share one live
continuous-batching engine through the :class:`repro.serve.RolloutService`
serving tier. A "gold" tenant (weight 3) and a "bronze" tenant (weight 1)
each queue a burst of streaming prompt jobs behind a small admission
window; the stride scheduler hands gold ~3/4 of the window, and each
job's tokens stream back incrementally while later jobs are still queued.

    PYTHONPATH=src python examples/serve_multi_tenant.py
"""
import argparse

import jax

from repro.configs import get_config
from repro.core import EngineHandle, LLMProxy
from repro.data.tokenizer import TOKENIZER
from repro.models import Model
from repro.rl.engine import InferenceEngine
from repro.serve import JobState, RolloutJob, RolloutService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny")
    ap.add_argument("--jobs-per-tenant", type=int, default=6)
    ap.add_argument("--max-new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(model, params, max_slots=4, max_len=256, seed=0)
    proxy = LLMProxy([EngineHandle(eng, "H20")])

    with RolloutService(proxy, max_inflight=4) as svc:
        svc.register_tenant("gold", weight=3.0)
        svc.register_tenant("bronze", weight=1.0)
        svc.start()

        tickets = []
        for i in range(args.jobs_per_tenant):
            for name in ("gold", "bronze"):
                tickets.append(svc.submit(name, RolloutJob(
                    kind="prompt",
                    prompt=TOKENIZER.encode(f"request {i} from {name}: ",
                                            bos=True),
                    max_new_tokens=args.max_new_tokens,
                    temperature=0.8)))

        for tk in tickets:
            text = "".join(TOKENIZER.decode(c.tokens) for c in tk.stream)
            assert tk.wait(timeout=120) == JobState.DONE
            wait_ms = 1e3 * (tk.t_admit - tk.t_submit)
            print(f"[{tk.job_id}] queued {wait_ms:6.1f} ms -> {text!r}")

        for name, st in svc.stats().items():
            print(f"tenant={name} weight={st['weight']} "
                  f"admitted={st['admitted']} completed={st['completed']} "
                  f"streamed_tokens={st['stream_tokens']} "
                  f"vtime={st['vtime']}")


if __name__ == "__main__":
    main()

"""Fault-tolerant agentic RL training (paper §8).

Runs the live RollArt pipeline under the FT supervisor: every weight-sync
barrier pairs a train-state checkpoint with a ROLLOUT snapshot (env
manager state machines, engine KV slots, buffered samples, pending
serverless rewards), failures are injected at the paper's ~1-in-10
iteration rate, and each one is recovered from the latest snapshot
without restarting training. At the end the trainer itself is "killed"
and restarted from the latest intact pair, proving the restart path.

    PYTHONPATH=src python examples/train_fault_tolerant.py
"""
import shutil
import tempfile

import jax

from repro.configs import get_config
from repro.core import (EngineHandle, LiveRLRunner, LLMProxy, RunnerConfig,
                        ServerlessPlatform)
from repro.ft import FTConfig, FTSupervisor, FailureInjector, restore_latest
from repro.models import Model
from repro.rewards.rule_based import format_bonus_reward
from repro.rl.engine import InferenceEngine
from repro.rl.trainer import (default_optimizer, init_train_state,
                              make_grpo_train_step)


def make_runner(state):
    cfg = get_config("tiny")
    model = Model(cfg, remat=False)
    opt = default_optimizer(1e-3)
    eng = InferenceEngine(model, state.params, max_slots=8, max_len=512,
                          seed=3)
    proxy = LLMProxy([EngineHandle(eng, "local")])
    return LiveRLRunner(
        RunnerConfig(batch_size=4, group_size=2, alpha=2, mode="rollart",
                     tasks=("math", "game"), max_new_tokens=24,
                     temperature=0.0),
        proxy, state, jax.jit(make_grpo_train_step(model, opt)),
        ServerlessPlatform(), format_bonus_reward, seq_len=512)


def main():
    ckpt = tempfile.mkdtemp(prefix="ft_example_")
    try:
        model = Model(get_config("tiny"), remat=False)
        state = init_train_state(model, jax.random.PRNGKey(0),
                                 default_optimizer(1e-3))
        runner = make_runner(state)
        sup = FTSupervisor(
            runner,
            FTConfig(snapshot_every=1, keep_last=3),
            ckpt_dir=ckpt,
            injector=FailureInjector(rate=0.1, seed=7))
        with runner:
            sup.run_steps(6)
        sup.snapshotter.wait()
        sup.close()
        for line in sup.log:
            print("ft:", line)
        print(f"supervised run: {len(runner.history)} steps, "
              f"{len(sup.events)} failures injected, "
              f"{sum(e.recovered_tokens for e in sup.events)} tokens "
              "recovered from snapshots")

        # trainer failure: restart from the latest intact pair
        print("killing the trainer ...")
        like = init_train_state(model, jax.random.PRNGKey(0),
                                default_optimizer(1e-3))
        restored, step = restore_latest(ckpt, like, make_runner)
        with restored:
            restored.run_steps(2)
        print(f"restarted from paired checkpoint at step {step}, "
              f"continued to step {restored.history[-1].step + step}; "
              f"deduped replays: {restored.buffer.total_deduped}")
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()

"""End-to-end agentic RL driver (deliverable (b)): the full RollArt pipeline
— trajectory-level rollout against real environments through the LLMProxy,
serverless reward scoring, the bounded-staleness SampleBuffer, GRPO updates,
and the six-step weight-sync protocol with KV-cache recomputation — on a
small model, live on CPU.

    PYTHONPATH=src python examples/train_agentic_rl.py --steps 20
"""
import argparse
import time

import jax

from repro.configs import get_config
from repro.core import (EngineHandle, LiveRLRunner, LLMProxy, RunnerConfig,
                        ServerlessPlatform)
from repro.models import Model
from repro.rewards.rule_based import format_bonus_reward
from repro.rl.engine import InferenceEngine
from repro.rl.trainer import (default_optimizer, init_train_state,
                              make_grpo_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--group", type=int, default=4)
    ap.add_argument("--alpha", type=int, default=1)
    ap.add_argument("--tasks", default="math,game")
    ap.add_argument("--mode", default="rollart",
                    choices=["rollart", "areal", "one_off", "sync",
                             "sync_plus"])
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--max-new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    model = Model(cfg, remat=False)
    opt = default_optimizer(args.lr)
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    step_fn = jax.jit(make_grpo_train_step(model, opt))

    # two engines on different "hardware classes"; prefill-heavy tasks are
    # routed to the compute pool, decode-heavy to the bandwidth pool (R1)
    e1 = InferenceEngine(model, state.params, max_slots=8, max_len=640,
                         seed=1)
    e2 = InferenceEngine(model, state.params, max_slots=8, max_len=640,
                         seed=2)
    proxy = LLMProxy(
        [EngineHandle(e1, "H800", "gen-compute"),
         EngineHandle(e2, "H20", "gen-bandwidth")],
        hw_affinity={"frozenlake": "H800", "webshop": "H800",
                     "swe": "H800", "math": "H20", "game": "H20",
                     "default": "H20"})

    t0 = time.time()
    with LiveRLRunner(
            RunnerConfig(batch_size=args.batch, group_size=args.group,
                         alpha=args.alpha, mode=args.mode,
                         tasks=tuple(args.tasks.split(",")),
                         max_new_tokens=args.max_new_tokens),
            proxy, state, step_fn, ServerlessPlatform(),
            format_bonus_reward, seq_len=640) as runner:
        for h in runner.run_steps(args.steps):
            print(f"step {h.step:3d}  loss {h.loss:+.4f}  "
                  f"reward {h.reward_mean:+.3f}  wall {h.wall_s:5.1f}s  "
                  f"ovl {h.decode_during_train:4d}  "
                  f"evicted {h.evicted}  aborted {h.aborted}")
        stats = runner.proxy.stats()
        print(f"\ndone in {time.time() - t0:.0f}s; routed by pool: "
              f"{stats['routed_by_pool']}; serverless reward calls: "
              f"{runner.serverless.stats.invocations}; weight versions "
              f"published: {runner.store.latest_version + 1}")


if __name__ == "__main__":
    main()

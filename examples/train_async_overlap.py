"""Live demonstration of genuine train/rollout overlap (paper principle 2,
trajectory-level asynchrony): the rollout side — proxy pump, EnvManager
completions, async serverless reward scoring — runs on a persistent
background worker thread that keeps filling the SampleBuffer while the
trainer thread executes the six-step weight-sync protocol. The per-step
``ovl`` column counts decode tokens the engines generated WHILE train_step
ran; run with ``--mode sync`` to see it collapse to zero.

    PYTHONPATH=src python examples/train_async_overlap.py --steps 6
    PYTHONPATH=src python examples/train_async_overlap.py --mode sync
    PYTHONPATH=src python examples/train_async_overlap.py --mode one_off
"""
import argparse
import time

import jax

from repro.configs import get_config
from repro.core import (EngineHandle, LiveRLRunner, LLMProxy, RunnerConfig,
                        ServerlessPlatform)
from repro.models import Model
from repro.rewards.rule_based import format_bonus_reward
from repro.rl.engine import InferenceEngine
from repro.rl.trainer import (default_optimizer, init_train_state,
                              make_grpo_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny")
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--group", type=int, default=2)
    ap.add_argument("--alpha", type=int, default=1)
    ap.add_argument("--mode", default="rollart",
                    choices=["rollart", "areal", "one_off", "sync",
                             "sync_plus"])
    ap.add_argument("--tasks", default="game")
    ap.add_argument("--max-new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    model = Model(cfg, remat=False)
    opt = default_optimizer(1e-3)
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    eng = InferenceEngine(model, state.params, max_slots=8, max_len=256,
                          seed=3)
    proxy = LLMProxy([EngineHandle(eng, "H20")])

    t0 = time.time()
    with LiveRLRunner(
            RunnerConfig(batch_size=args.batch, group_size=args.group,
                         alpha=args.alpha, mode=args.mode,
                         tasks=tuple(args.tasks.split(",")),
                         max_new_tokens=args.max_new_tokens),
            proxy, state, jax.jit(make_grpo_train_step(model, opt)),
            ServerlessPlatform(), format_bonus_reward,
            seq_len=256) as runner:
        print(f"mode={args.mode} "
              f"({'threaded rollout worker' if runner.threaded else 'cooperative'})")
        for h in runner.run_steps(args.steps):
            print(f"step {h.step:2d}  loss {h.loss:+.4f}  "
                  f"reward {h.reward_mean:+.3f}  wall {h.wall_s:5.2f}s  "
                  f"ovl {h.decode_during_train:4d} decode toks  "
                  f"batch_from_step {h.batch_fetched_step:2d}  "
                  f"evicted {h.evicted}  aborted {h.aborted}")
        total_ovl = sum(h.decode_during_train for h in runner.history)
        print(f"\ndone in {time.time() - t0:.0f}s; decode tokens generated "
              f"during train_step: {total_ovl} "
              f"({'overlap is live' if total_ovl else 'no overlap — synchronous baseline'}); "
              f"reward calls: {runner.serverless.stats.invocations}; "
              f"weight versions published: {runner.store.latest_version + 1}")


if __name__ == "__main__":
    main()

"""Quickstart: pretrain a tiny model on the synthetic corpus, then sample
from it through the continuous-batching engine.

    PYTHONPATH=src python examples/quickstart.py --steps 30
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import lm_batches
from repro.data.tokenizer import TOKENIZER
from repro.models import Model
from repro.rl.engine import GenRequest, InferenceEngine
from repro.rl.trainer import (default_optimizer, init_train_state,
                              make_lm_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    model = Model(cfg, remat=False)
    opt = default_optimizer(args.lr)
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_lm_train_step(model, opt))
    print(f"{cfg.name}: {sum(x.size for x in jax.tree.leaves(state.params)):,}"
          " params")

    for i, batch in enumerate(lm_batches(TOKENIZER, args.seq, args.batch,
                                         args.steps)):
        state, metrics = step(state, {k: jnp.asarray(v)
                                      for k, v in batch.items()})
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(metrics['loss']):.3f}")

    # sample from the trained model
    eng = InferenceEngine(model, state.params, max_slots=2, max_len=256)
    prompt = TOKENIZER.encode("the agent ", bos=True)
    eng.add_request(GenRequest(request_id="s", prompt=prompt,
                               max_new_tokens=40, temperature=0.8))
    eng.run_until_idle()
    res = eng.pop_result("s")
    print("sample:", repr(TOKENIZER.decode(prompt + res.tokens)))


if __name__ == "__main__":
    main()

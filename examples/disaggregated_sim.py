"""Cluster-scale what-if tool: run the calibrated discrete-event simulation
of the disaggregated pipeline at the paper's scale and compare coordination
modes or resource splits.

    PYTHONPATH=src python examples/disaggregated_sim.py \\
        --model qwen3-32b --modes sync_plus rollart --steps 5
"""
import argparse

from repro.core.simrl import run_sim

POOLS = {
    "baseline": (("H800", 96),),
    "mixed": (("H800", 64), ("H20", 32)),
}
AFFINITY = {"math": "H20", "game": "H20", "default": "H800"}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="qwen3-32b")
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--alpha", type=int, default=1)
    ap.add_argument("--modes", nargs="+",
                    default=["sync", "sync_plus", "one_off", "areal",
                             "rollart"])
    args = ap.parse_args()

    print(f"{'mode':12s} {'pools':10s} {'step_s':>9s} {'tok/s':>9s} "
          f"{'groups_ok':>9s} {'dead':>5s} {'aborted':>7s}")
    for mode in args.modes:
        mixed = mode == "rollart"
        m = run_sim(
            mode=mode, model=args.model, batch_size=args.batch,
            num_steps=args.steps, alpha=args.alpha,
            gen_pools=POOLS["mixed" if mixed else "baseline"],
            hw_affinity=AFFINITY if mixed else None,
            reward_serverless=(mode != "sync"),
            async_weight_sync=(mode in ("areal", "rollart")))
        print(f"{mode:12s} {'mixed' if mixed else 'H800x96':10s} "
              f"{m.avg_step_s:9.1f} {m.throughput_tok_s:9.0f} "
              f"{m.groups_completed:9d} {m.groups_dead:5d} "
              f"{m.aborted:7d}")


if __name__ == "__main__":
    main()

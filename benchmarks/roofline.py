"""§Roofline: per (arch x input-shape) roofline terms on the single-pod
production mesh, derived from the dry-run artifacts.

Methodology (EXPERIMENTS.md §Roofline): XLA cost analysis counts a
while/scan body once, so FLOPs / bytes-accessed / collective-bytes are
extracted from the UNROLLED depth-1 and depth-2 builds and linearly
extrapolated to full depth:  term(N) = t1 + (N-1) * (t2 - t1).
The full-depth scanned compile provides the lowering + HBM-fit proof.

Terms (per assignment):
  t_compute    = HLO_FLOPs   / peak            (197 TFLOP/s bf16, v5e)
  t_memory     = HLO_bytes   / HBM bw          (819 GB/s)
  t_collective = coll_bytes  / link bw         (50 GB/s/link)
All are per-device quantities of the SPMD program (equivalent to the
global/chips form).
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

from benchmarks.common import Bench, fmt
from repro.configs.base import ARCH_IDS, INPUT_SHAPES, get_config
from repro.launch.hlo_costs import HBM_CAP, roofline_terms
from repro.launch.specs import TRAIN_MICROBATCHES

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                          "dryrun")


def _load(arch: str, shape: str, mesh: str, tag: str) -> Optional[Dict]:
    p = os.path.join(DRYRUN_DIR, f"{arch}__{shape}__{mesh}__{tag}.json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        d = json.load(f)
    return d if d.get("ok") else None


def extrapolated_costs(arch: str, shape: str) -> Optional[Dict]:
    d1 = _load(arch, shape, "single", "d1u")
    d2 = _load(arch, shape, "single", "d2u")
    full = _load(arch, shape, "single", "full")
    if not (d1 and d2 and full):
        return None
    n = get_config(arch).num_periods
    # gradient-accumulation scan bodies are counted once by cost analysis;
    # scale by the microbatch trip count (§Perf iter 5)
    mb = TRAIN_MICROBATCHES.get(arch, 1) if full["kind"] == "train" else 1

    def extra(key):
        return (d1[key] + (n - 1) * (d2[key] - d1[key])) * mb

    costs = {
        "flops_per_device": extra("flops_per_device"),
        "bytes_per_device": extra("bytes_per_device"),
        "collective_bytes_per_device": extra("collective_bytes_per_device"),
        "memory": full.get("memory", {}),
        "kind": full["kind"],
    }
    if "collective_bytes_adjusted" in d1 and "collective_bytes_adjusted" in d2:
        costs["collective_bytes_adjusted"] = extra("collective_bytes_adjusted")
    return costs


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    return 2.0 * n_active * tokens


_SUGGEST = {
    "t_compute": ("compute-bound: raise MXU utilization (larger matmul "
                  "tiles, fuse small einsums, reduce remat recompute)"),
    "t_memory": ("memory-bound: cut HBM traffic (bf16 end-to-end, flash/"
                 "chunked attention instead of materialized scores, fuse "
                 "elementwise chains, larger per-step arithmetic intensity)"),
    "t_collective": ("collective-bound: reshard to shrink all-gathers "
                     "(2D weight-stationary, overlap collectives with "
                     "compute, move batch off the bottleneck axis)"),
}


def run(emit_rows: bool = True):
    b = Bench("roofline")
    table = []
    for arch in ARCH_IDS:
        for shape in INPUT_SHAPES:
            costs = extrapolated_costs(arch, shape)
            if costs is None:
                b.row(f"{arch}_{shape}", "MISSING dryrun artifacts")
                continue
            terms = roofline_terms(costs)
            # adjusted collective term: discounts XLA:CPU AR/AG-then-slice
            # patterns that TPU folds to reduce-scatter / local copies
            adj = costs.get("collective_bytes_adjusted")
            t_coll_adj = (adj / 50e9) if adj is not None \
                else terms["t_collective"]
            terms_adj = dict(terms, t_collective=t_coll_adj)
            dom = max(terms_adj, key=terms_adj.get)
            mf = model_flops(arch, shape)
            hlo_global = costs["flops_per_device"] * 256
            ratio = mf / max(hlo_global, 1.0)
            peak = costs.get("memory", {}).get("peak_bytes_est", 0)
            row = {
                "arch": arch, "shape": shape,
                "t_compute_s": terms["t_compute"],
                "t_memory_s": terms["t_memory"],
                "t_collective_s": terms["t_collective"],
                "t_collective_adj_s": t_coll_adj,
                "dominant": dom,
                "model_flops": mf,
                "hlo_flops_global": hlo_global,
                "useful_ratio": ratio,
                "hbm_peak_frac_cpu_raw": peak / HBM_CAP,
                "suggestion": _SUGGEST[dom],
            }
            table.append(row)
            if emit_rows:
                b.row(f"{arch}|{shape}",
                      f"tc={terms['t_compute']:.3g}s tm={terms['t_memory']:.3g}s "
                      f"tcoll={terms['t_collective']:.3g}s "
                      f"tcoll_adj={t_coll_adj:.3g}s dom={dom} "
                      f"useful={ratio:.2f}")
    out = os.path.join(os.path.dirname(__file__), "..", "results",
                       "roofline.json")
    with open(out, "w") as f:
        json.dump(table, f, indent=1)
    b.row("table_rows", len(table), "40 (10 archs x 4 shapes)")
    b.save()
    return table


if __name__ == "__main__":
    run()

"""Table 4 + Fig. 14a: asynchronous cross-cluster weight transfer.

Analytic decomposition from the fitted Mooncake constants (push 0.46 GB/s
over Ethernet, pull 2.5 GB/s intra-cluster, 72-78% of the pull hidden by
rollout overlap) + an e2e async-vs-blocking comparison (paper: 1.10-1.16x
end-to-end step-time reduction)."""
from benchmarks.common import Bench, fmt
from repro.configs import get_config
from repro.core.hardware import PERF
from repro.core.simrl import MOONCAKE_PULL_GBS, MOONCAKE_PUSH_GBS, run_sim

PAPER = {  # Table 4 (seconds)
    "qwen3-8b": (38.6, 32.4, 6.2, 1.4),
    "qwen3-14b": (84.1, 67.8, 16.3, 5.1),
    "qwen3-32b": (157.0, 127.3, 29.7, 9.6),
}


def run(steps=4):
    b = Bench("weight_sync_tab4")
    for model, (naive_p, push_p, pull_p, exposed_p) in PAPER.items():
        gb = PERF.weight_bytes(get_config(model)) / 1e9
        push = gb / MOONCAKE_PUSH_GBS
        pull = gb / MOONCAKE_PULL_GBS
        exposed = pull * 0.28
        b.row(f"{model}_naive_s", fmt(push + pull, 1), f"{naive_p} (Tab 4)")
        b.row(f"{model}_push_s", fmt(push, 1), f"{push_p} (Tab 4)")
        b.row(f"{model}_pull_s", fmt(pull, 1), f"{pull_p} (Tab 4)")
        b.row(f"{model}_exposed_s", fmt(exposed, 1),
              f"{exposed_p} (Tab 4)")
    # Fig 14a e2e: async vs blocking weight sync in the full pipeline
    common = dict(mode="rollart", model="qwen3-14b", batch_size=256,
                  num_steps=steps, gen_pools=(("H800", 64), ("H20", 32)),
                  hw_affinity={"math": "H20", "game": "H20",
                               "default": "H800"}, reward_serverless=True)
    m_async = run_sim(async_weight_sync=True, **common)
    m_block = run_sim(async_weight_sync=False, **common)
    b.row("e2e_async_speedup",
          fmt(m_block.avg_step_s / m_async.avg_step_s),
          "1.10-1.16 (Fig 14a)")
    b.save()
    return b


if __name__ == "__main__":
    run()

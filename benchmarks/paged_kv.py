"""Paged-KV benchmark: the tentpole evidence for the paged decode plane
(shared page pool + radix prefix cache + compacted dispatch). Three
asserted claims on the tiny config (XLA:CPU):

  occupancy        (HEADLINE) — decode throughput with ONE active stream
      on an 8-slot engine. The dense engine pays all ``max_slots``
      attention rows on every dispatch; the paged engine compacts the
      batch to the power-of-two bucket of the ACTIVE count (1 row), so
      low-occupancy serving — the long-tail regime §6.3 routes to the
      bandwidth pool — stops paying for empty slots. Target >= 1.5x.
  prefix_forking   — redundancy-2 workload (every prompt submitted
      twice, the paper's redundant-rollout setting): the second
      admission forks the first prompt's pages out of the radix cache
      and prefills only the tail page, cutting prefilled tokens
      >= 40%. Greedy outputs stay byte-identical to the dense engine.
  incremental_snapshot — page-granularity dirty tracking: after a
      barrier capture, a capture taken when only one slot advanced
      gathers just that slot's freshly written pages — fewer bytes than
      the full per-slot row the dense capture path device_gets.

Greedy byte-parity paged-vs-dense is asserted on every workload the
numbers come from.
"""
import argparse
import time

import jax
import numpy as np

from benchmarks.common import Bench, fmt
from repro.configs import get_config
from repro.models import Model
from repro.rl.engine import GenRequest, InferenceEngine

PAGE = 16


def _engine(model, params, paged, *, slots=8, max_len=256, k=8, seed=1):
    return InferenceEngine(model, params, max_slots=slots, max_len=max_len,
                           seed=seed, steps_per_dispatch=k, paged=paged,
                           page_size=PAGE)


def _serve(eng, prompts, tag, max_new):
    for i, p in enumerate(prompts):
        eng.add_request(GenRequest(request_id=f"{tag}{i}", prompt=list(p),
                                   max_new_tokens=max_new, temperature=0.0))
    eng.run_until_idle()
    return [eng.pop_result(f"{tag}{i}").tokens for i in range(len(prompts))]


def _tps(eng, prompts, tag, max_new):
    d0 = eng.decode_tokens
    t0 = time.perf_counter()
    out = _serve(eng, prompts, tag, max_new)
    return (eng.decode_tokens - d0) / (time.perf_counter() - t0), out


def _occupancy(b, model, params, max_new, reps):
    """1-of-8 slot occupancy: single greedy stream, median of reps."""
    rng = np.random.RandomState(0)
    prompt = [1] + list(rng.randint(3, model.cfg.vocab_size - 1, size=11))
    tps = {}
    streams = {}
    for paged in (False, True):
        eng = _engine(model, params, paged)
        _serve(eng, [prompt], "warm", max_new)       # compile
        vals = []
        for r in range(reps):
            v, out = _tps(eng, [prompt], f"m{r}", max_new)
            vals.append(v)
        tps[paged] = sorted(vals)[len(vals) // 2]
        streams[paged] = out
    assert streams[True] == streams[False], "paged diverged from dense"
    speed = tps[True] / tps[False]
    b.row("occupancy_dense_tokens_per_s", fmt(tps[False], 1))
    b.row("occupancy_paged_tokens_per_s", fmt(tps[True], 1))
    b.row("occupancy_speedup_1_of_8", fmt(speed, 2), ">=1.5")
    assert speed >= 1.5, (
        f"paged 1-of-8 occupancy speedup {speed:.2f} < 1.5")


def _prefix_forking(b, model, params, n_pairs, max_new):
    """Redundancy-2 shared prompts: prefilled tokens drop >= 40%."""
    rng = np.random.RandomState(1)
    bases = [[1] + list(rng.randint(3, model.cfg.vocab_size - 1, size=129))
             for _ in range(n_pairs)]
    prompts = [p for base in bases for p in (base, base)]   # redundancy 2
    outs, filled = {}, {}
    for paged in (False, True):
        eng = _engine(model, params, paged, seed=2)
        outs[paged] = _serve(eng, prompts, "fork", max_new)
        filled[paged] = eng.prefill_tokens
        if paged:
            st = eng.stats()
            b.row("prefix_hits", st["prefix_hits"])
            b.row("shared_prefix_tokens", st["shared_prefix_tokens"])
    assert outs[True] == outs[False], "forked streams diverged from dense"
    red = 1.0 - filled[True] / filled[False]
    b.row("prefill_tokens_dense", filled[False])
    b.row("prefill_tokens_paged", filled[True])
    b.row("prefill_reduction_redundancy2", fmt(red, 3), ">=0.40")
    assert red >= 0.40, f"prefix forking cut only {red:.1%} of prefill"


def _incremental_snapshot(b, model, params, max_new):
    """Dirty-page capture bytes vs the full dense per-slot gather."""
    eng = _engine(model, params, True, seed=3)
    rng = np.random.RandomState(2)
    long_p = [1] + list(rng.randint(3, model.cfg.vocab_size - 1, size=30))
    eng.add_request(GenRequest(request_id="a", prompt=long_p,
                               max_new_tokens=max_new, temperature=0.0))
    eng.add_request(GenRequest(request_id="b", prompt=long_p[:12],
                               max_new_tokens=2, temperature=0.0))
    eng.step()
    eng.step()                       # slot b finishes inside these steps
    eng.capture_kv_incremental()     # barrier capture absorbs history
    for _ in range(2):               # ... now only slot a advances
        eng.step()
    cap = eng.capture_kv_incremental()
    n_active = sum(1 for rec in cap["slots"])
    full = n_active * sum(int(np.asarray(leaf).nbytes) for leaf in
                          jax.tree.leaves(model.init_cache(1, eng.max_len)))
    b.row("incremental_capture_bytes", cap["captured_bytes"])
    b.row("full_capture_bytes", full)
    b.row("incremental_fraction",
          fmt(cap["captured_bytes"] / full, 3), "<1.0")
    assert 0 < cap["captured_bytes"] < full, (
        f"incremental capture {cap['captured_bytes']}B not below the "
        f"full per-slot gather {full}B")
    eng.run_until_idle()


def run(smoke: bool = False, save: bool = True):
    b = Bench("paged_kv")
    cfg = get_config("tiny")
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    if smoke:
        _occupancy(b, model, params, max_new=48, reps=3)
        _prefix_forking(b, model, params, n_pairs=2, max_new=8)
        _incremental_snapshot(b, model, params, max_new=48)
    else:
        _occupancy(b, model, params, max_new=96, reps=5)
        _prefix_forking(b, model, params, n_pairs=4, max_new=16)
        _incremental_snapshot(b, model, params, max_new=96)
    if save:
        b.save()
    return b


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI; same asserted claims")
    args = ap.parse_args(argv)
    run(smoke=args.smoke, save=not args.smoke)


if __name__ == "__main__":
    main()

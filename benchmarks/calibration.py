"""Fig. 4 validation: cost-equivalent hardware affinity ratios, measured the
way the paper does — END-TO-END batched rollout time over varying batch
sizes (throughput-bound, not single-stream latency).

Prefill-heavy FrozenLake re-encodes a growing history over many turns
(compute-bound -> 2x H800 wins); decode-heavy GEM-math emits long CoT over
few turns (bandwidth-bound -> 6x H20, the cost-equivalent config, wins).
Paper: H800 0.53x on prefill-heavy; H20 0.49x-0.79x on decode-heavy.
"""
from benchmarks.common import Bench, fmt
from repro.configs import get_config
from repro.core.hardware import H20, H800, PERF


def batch_rollout_time(cfg, hw, n_dev, batch, turns, obs, resp,
                       prefix_cache=0.5):
    """Aggregate two-phase model: total prefill FLOPs on the pool's compute
    + total decode bytes (weights amortized over the batch + per-stream KV)
    on the pool's bandwidth."""
    flops = bw_bytes = 0.0
    ctx = 256.0
    kv_tok = PERF.kv_bytes_per_token(cfg)
    weights = 2.0 * cfg.active_param_count()
    for _ in range(turns):
        flops += batch * 2.0 * cfg.active_param_count() * ctx \
            * (1 - prefix_cache)
        bw_bytes += resp * (weights + batch * ctx * kv_tok)
        ctx += resp + obs
    t_prefill = flops / (n_dev * hw.tflops_bf16 * 1e12 * PERF.prefill_mfu)
    t_decode = bw_bytes / (n_dev * hw.hbm_bw_gbs * 1e9 * PERF.decode_bw_eff)
    return t_prefill + t_decode


def run():
    b = Bench("calibration_fig4")
    cfg = get_config("qwen3-8b")
    batch = 64
    fl = dict(batch=batch, turns=40, obs=600, resp=30)
    fl_h800 = batch_rollout_time(cfg, H800, 2, **fl)
    fl_h20 = batch_rollout_time(cfg, H20, 6, **fl)
    b.row("frozenlake_h800_over_h20", fmt(fl_h800 / fl_h20),
          "0.53 (paper Fig 4a)")
    m = dict(batch=batch, turns=3, obs=120, resp=8000)
    m_h800 = batch_rollout_time(cfg, H800, 2, **m)
    m_h20 = batch_rollout_time(cfg, H20, 6, **m)
    b.row("math_h20_over_h800", fmt(m_h20 / m_h800),
          "0.49-0.79 (paper Fig 4b)")
    b.save()
    return b


if __name__ == "__main__":
    run()

"""Live PD-disaggregation microbenchmark: the real-engine counterpart of
``benchmarks/pd_disagg.py`` (which predicts Table 5 in virtual time). The
same greedy request set runs through (a) a colocated two-engine proxy and
(b) a disaggregated 1P1D proxy, and we report per-pool prefill/decode token
counters plus real engine step counts, so the simulator's prefill/decode
split can be checked against actual engine behavior: all prefill tokens
must land on the prefill pool and all decode tokens on the decode pool,
with token-identical outputs."""
import jax
import numpy as np

from benchmarks.common import Bench, fmt
from repro.configs import get_config
from repro.core import EngineHandle, LLMProxy, build_pd_proxy
from repro.models import Model
from repro.rl.engine import GenRequest, InferenceEngine


def _serve(proxy, prompts, max_new):
    out = {}
    pumps = 0
    for i, p in enumerate(prompts):
        proxy.submit(GenRequest(request_id=f"r{i}", prompt=p,
                                max_new_tokens=max_new, temperature=0.0),
                     callback=lambda r: out.__setitem__(r.request_id, r))
    while proxy.busy:
        proxy.pump()
        pumps += 1
    return [out[f"r{i}"].tokens for i in range(len(prompts))], pumps


def run(n_requests=8, max_new=12):
    b = Bench("pd_disagg_live")
    cfg = get_config("tiny")
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = [list(rng.randint(3, cfg.vocab_size - 1,
                                size=int(rng.randint(4, 24))))
               for _ in range(n_requests)]

    col = LLMProxy([
        EngineHandle(InferenceEngine(model, params, max_slots=4,
                                     max_len=256, seed=1), "H800"),
        EngineHandle(InferenceEngine(model, params, max_slots=4,
                                     max_len=256, seed=2), "H20")])
    tokens_col, pumps_col = _serve(col, prompts, max_new)

    pd = build_pd_proxy(model, params, n_prefill=1, n_decode=1,
                        max_slots=4, max_len=256, seed=3)
    tokens_pd, pumps_pd = _serve(pd, prompts, max_new)

    b.row("greedy_parity", int(tokens_col == tokens_pd), "1 (identical)")
    b.row("colocated_pumps", pumps_col)
    b.row("pd_pumps", pumps_pd)
    b.row("pd_handoffs", pd.stats()["handoffs"], f"{n_requests}")
    for e in pd.stats()["engines"]:
        b.row(f"{e['pool']}_{e['role']}_prefill_tokens",
              e["prefill_tokens"],
              "all prefill on prefill pool" if e["role"] == "prefill"
              else "0")
        b.row(f"{e['pool']}_{e['role']}_decode_tokens", e["decode_tokens"],
              "0" if e["role"] == "prefill" else "all decode on decode pool")
        b.row(f"{e['pool']}_{e['role']}_engine_steps", e["steps"])
    # simulator cross-check handle: Table-5 speedups come from
    # benchmarks/pd_disagg.py; here we expose the live busy-step ratio the
    # simulator's decode model can be calibrated against
    busy = {e["role"]: e["busy_steps"] for e in pd.stats()["engines"]}
    b.row("decode_busy_steps", busy.get("decode", 0))
    b.row("prefill_admissions", pd.stats()["handoffs"])
    b.save()
    return b


if __name__ == "__main__":
    run()

"""Fig. 10c: scaling efficiency — Qwen3-14B, 64 -> 128 H800, throughput
normalized to Sync+ on 64 GPUs. Paper: RollArt 1.33-2.08x higher than the
baselines at scale (no hardware-affinity in this evaluation)."""
from benchmarks.common import Bench, fmt
from repro.core.simrl import run_sim


def run(steps=4):
    b = Bench("scaling_fig10c")
    base = None
    for total in (64, 96, 128):
        rollout = total - 32
        for mode, aws in (("sync_plus", False), ("one_off", False),
                          ("rollart", True)):
            m = run_sim(mode=mode, model="qwen3-14b", batch_size=256,
                        num_steps=steps, gen_pools=(("H800", rollout),),
                        reward_serverless=True, async_weight_sync=aws)
            if base is None:
                base = m.throughput_tok_s
            b.row(f"{mode}_{total}gpu_tput_norm",
                  fmt(m.throughput_tok_s / base),
                  "rollart 1.33-2.08x over baselines at 96-128")
    b.save()
    return b


if __name__ == "__main__":
    run()

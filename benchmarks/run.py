"""Benchmark harness: one entry per paper table/figure (DESIGN.md §6) plus
the kernel microbenchmarks and the §Roofline table.

Prints ``bench,metric,value,paper_target`` CSV and saves per-bench JSON
under results/bench/.
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks.common import header

ALL = [
    "calibration",      # Fig 4
    "step_breakdown",   # Fig 3
    "e2e_steptime",     # Fig 10a/b
    "scaling",          # Fig 10c
    "hw_affinity",      # Fig 11a (R1)
    "affinity_mapping",  # Table 2 ordering + live rebalancer (R1)
    "traj_vs_batch",    # Fig 11b (R2)
    "serverless_reward",  # Fig 6/12 (R3)
    "staleness_sweep",  # Fig 13 (R4)
    "weight_sync",      # Table 4 / Fig 14a
    "redundant_rollouts",  # Fig 14b
    "pd_disagg",        # Table 5
    "pd_disagg_live",   # Table 5 cross-check on the real engines
    "decode_hotpath",   # device-resident decode: K-step dispatch + donation
    "async_overlap",    # async rollout/train overlap on the live plane
    "fault_tolerance",  # §8: rollout checkpoint/restore vs scratch restart
    "traffic_gen",      # Rollout-as-a-Service: multi-tenant QoS under load
    "slo_burn",         # serving SLOs (TTFT / inter-token) + step budget
    "sharded_engine",   # TP engine groups: parity, sync bytes, PD 2->4
    "paged_kv",         # paged KV pool + prefix forking + dirty capture
    "kernels_bench",
    "roofline",         # §Roofline from the dry-run artifacts
]

FAST_SKIP = {"scaling", "staleness_sweep"}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--fast", action="store_true",
                    help="skip the slowest sweeps")
    ap.add_argument("--list", action="store_true",
                    help="print the registry (name,fast) and exit; fails "
                         "if any registered benchmark does not resolve")
    args = ap.parse_args(argv)
    if args.list:
        bad = 0
        for name in ALL:
            try:
                mod = __import__(f"benchmarks.{name}", fromlist=["run"])
                ok = callable(getattr(mod, "run", None))
            except Exception:  # noqa: BLE001
                ok = False
            bad += not ok
            tag = "fast-skip" if name in FAST_SKIP else "fast"
            print(f"{name},{tag}" + ("" if ok else ",UNRESOLVED"))
        return 1 if bad else 0
    names = args.only or [n for n in ALL
                          if not (args.fast and n in FAST_SKIP)]
    header()
    failures = 0
    for name in names:
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            mod.run()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},ERROR,,", flush=True)
            traceback.print_exc()
    print(f"run,complete,{len(names) - failures}/{len(names)},")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

"""Sharded engine groups benchmark: TP execution through the live stack.

Three experiments on >= 8 host devices (the module re-execs itself with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` when the current
process exposes fewer — so the registry entry works from any parent):

1. **Group-size sweep**: greedy decode throughput at TP degree 1/2/4 on
   a colocated engine, with byte-identical token parity asserted against
   the single-device run (the mesh changes placement, never tokens).
2. **Sharded weight sync**: push a new version as per-shard chunks
   through the MooncakeStore and swap it in via ``update_from_chunks``;
   reports chunked-push vs dense-push bytes, swap latency, and the
   no-full-copy accounting — the max per-device param footprint must be
   strictly below the full param footprint (asserted, not just logged).
3. **Unequal PD groups**: a live prefill(TP2) -> decode(TP4) plane runs
   greedy requests to completion with handoff re-sharding, parity
   asserted vs single-device.

    PYTHONPATH=src python -m benchmarks.sharded_engine [--smoke]
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

from benchmarks.common import Bench, fmt

NDEV = 8
_FLAG = f"--xla_force_host_platform_device_count={NDEV}"


def _reexec(smoke: bool) -> int:
    """Run this module in a child process that sees NDEV host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + _FLAG).strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"), root,
                    env.get("PYTHONPATH", "")) if p)
    cmd = [sys.executable, "-m", "benchmarks.sharded_engine"]
    if smoke:
        cmd.append("--smoke")
    proc = subprocess.run(cmd, env=env, cwd=root)
    if proc.returncode != 0:
        raise RuntimeError(
            f"sharded_engine child exited {proc.returncode}")
    return proc.returncode


def run(smoke: bool = False, save: bool = True):
    import jax
    if len(jax.devices()) < NDEV:
        _reexec(smoke)
        return

    import numpy as np

    from repro.configs import get_config
    from repro.core import build_pd_proxy
    from repro.core.weightstore import (MooncakeStore, pull_param_chunks,
                                        push_params, push_params_sharded)
    from repro.distributed.sharding import model_axis_dims
    from repro.launch.mesh import allocate_engine_devices, make_group_mesh
    from repro.models import Model
    from repro.rl.engine import GenRequest, InferenceEngine

    b = Bench("sharded_engine")
    cfg = get_config("tiny").with_(name="tiny-tp", num_kv_heads=4)
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    full_bytes = sum(int(np.asarray(x).nbytes)
                     for x in jax.tree.leaves(params))
    new_tokens = 16 if smoke else 64
    prompts = [[1, 5, 7, 9, 3], [1, 2, 3], [1, 9, 9, 4, 2, 6]]

    def mesh(n):
        return (None if n == 1
                else make_group_mesh(allocate_engine_devices([n])[0]))

    def drive(eng, n_new):
        for i, p in enumerate(prompts):
            eng.add_request(GenRequest(request_id=f"r{i}", prompt=list(p),
                                       max_new_tokens=n_new,
                                       temperature=0.0))
        eng.run_until_idle()
        return [eng.pop_result(f"r{i}").tokens
                for i in range(len(prompts))]

    # --- 1. group-size sweep -------------------------------------------
    ref = None
    for n in (1, 2, 4):
        eng = InferenceEngine(model, params, max_slots=4, max_len=256,
                              mesh=mesh(n))
        drive(eng, 4)                       # warm the jit caches
        eng2 = InferenceEngine(model, params, max_slots=4, max_len=256,
                               mesh=mesh(n))
        t0 = time.time()
        toks = drive(eng2, new_tokens)
        dt = time.time() - t0
        if n == 1:
            ref = toks
        else:
            assert toks == ref, f"TP{n} diverged from single-device greedy"
        dec = eng2.stats()["decode_tokens"]
        b.row(f"tp{n}_decode_tok_s", fmt(dec / max(dt, 1e-9), 1))
        b.row(f"tp{n}_greedy_parity", int(toks == ref), "1")
        if n > 1:
            per_dev = eng2.param_device_bytes()
            b.row(f"tp{n}_max_device_param_mb",
                  fmt(max(per_dev.values()) / 2**20, 3))

    # --- 2. sharded weight sync ----------------------------------------
    params_v1 = model.init(jax.random.PRNGKey(1))
    dims = model_axis_dims(params_v1, 4)
    dense_store = MooncakeStore(bucket_mb=1)
    dense_bytes = push_params(dense_store, params_v1, 1)
    store = MooncakeStore(bucket_mb=1)
    chunk_bytes = push_params_sharded(store, params_v1, 1, 4, dims)
    eng = InferenceEngine(model, params, max_slots=4, max_len=256,
                          mesh=mesh(4))
    drive(eng, 4)                            # in-flight state not needed;
    #                                          warm caches for honest swap
    chunks, version = pull_param_chunks(store, params_v1)
    t0 = time.time()
    eng.update_from_chunks(chunks, version)
    swap_s = time.time() - t0
    per_dev = eng.param_device_bytes()
    assert max(per_dev.values()) < full_bytes, (
        "a device of the TP4 group holds a full param copy: "
        f"{max(per_dev.values())} >= {full_bytes}")
    b.row("param_full_mb", fmt(full_bytes / 2**20, 3))
    b.row("sync_push_dense_mb", fmt(dense_bytes / 2**20, 3))
    b.row("sync_push_chunked_mb", fmt(chunk_bytes / 2**20, 3))
    b.row("sync_swap_s", fmt(swap_s, 4))
    b.row("sync_host_chunk_mb", fmt(eng.stats()["sync_bytes"] / 2**20, 3))
    b.row("tp4_sync_max_device_param_mb",
          fmt(max(per_dev.values()) / 2**20, 3),
          f"< {fmt(full_bytes / 2**20, 3)}")
    b.row("no_full_copy_per_device", 1, "1")

    # --- 3. unequal PD groups ------------------------------------------
    proxy = build_pd_proxy(model, params, max_slots=4, max_len=256,
                           seed=7, prefill_devices_per_engine=2,
                           decode_devices_per_engine=4)
    out = {}
    for i, p in enumerate(prompts):
        proxy.submit(GenRequest(request_id=f"r{i}", prompt=list(p),
                                max_new_tokens=new_tokens,
                                temperature=0.0),
                     callback=lambda r: out.__setitem__(r.request_id, r))
    t0 = time.time()
    pumps = 0
    while proxy.busy:
        proxy.pump()
        pumps += 1
        assert pumps < 20000, "PD plane did not drain"
    dt = time.time() - t0
    toks = [out[f"r{i}"].tokens for i in range(len(prompts))]
    assert toks == ref, "PD(2->4) diverged from single-device greedy"
    st = proxy.stats()
    b.row("pd_2to4_handoffs", st["handoffs"], str(len(prompts)))
    b.row("pd_2to4_greedy_parity", 1, "1")
    b.row("pd_2to4_wall_s", fmt(dt, 2))
    proxy.release_bindings()

    if save:
        b.save()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short decode lengths (CI)")
    args = ap.parse_args(argv)
    run(smoke=args.smoke)
    return 0


if __name__ == "__main__":
    sys.exit(main())

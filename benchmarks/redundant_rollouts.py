"""Fig. 14b: redundant environment rollouts — launch more groups than
needed, cancel stragglers once the target count completes. Paper: speedup
grows with group count and redundancy, up to 1.62x."""
from benchmarks.common import Bench, fmt
from repro.core.simrl import run_sim


def run(steps=4):
    b = Bench("redundant_fig14b")
    for group_size in (4, 8):
        base = None
        for red in (1.0, 1.25, 1.5, 2.0):
            m = run_sim(mode="sync_plus", model="qwen3-8b", batch_size=128,
                        group_size=group_size, num_steps=steps,
                        redundancy=red, gen_pools=(("H800", 32),),
                        tasks=("math", "swe"), reward_serverless=True,
                        async_weight_sync=False)
            r = sum(m.rollout_s) / max(len(m.rollout_s), 1)
            if red == 1.0:
                base = r
            b.row(f"g{group_size}_red{red}_rollout_speedup",
                  fmt(base / r), "up to 1.62 (Fig 14b)")
    b.save()
    return b


if __name__ == "__main__":
    run()

"""Hardware-affinity workload mapping for the LIVE data plane (§5.2,
Table 2, Fig. 4): validates the cost-normalized throughput ordering of
placements on a mixed H800/H20 pool — role-affine (compute-bound prefill
on H800, bandwidth-bound decode on H20) must beat both the anti-affine
flip and the homogeneous baselines — then runs the real pipeline through
a ResourceManager-backed proxy and exercises the dynamic prefill<->decode
rebalancer (role switch + device-group re-bind recorded in StepMetrics).
"""
import jax

from benchmarks.common import Bench, fmt
from repro.configs import get_config
from repro.core import (H20, H800, PERF, RebalancerConfig, ResourceManager,
                        LiveRLRunner, RunnerConfig, ServerlessPlatform,
                        build_pd_proxy)
from repro.models import Model
from repro.rewards.rule_based import REWARD_FNS
from repro.rl.trainer import (default_optimizer, init_train_state,
                              make_grpo_train_step)

# Representative agentic workload: long accumulated multi-turn context,
# moderate per-turn decode (paper §3 Fig. 3) — prefill compute-bound,
# decode bandwidth-bound.
PROMPT_TOKENS = 4096
NEW_TOKENS = 256
CONCURRENCY = 32


def modeled(model_id="qwen3-8b"):
    """Table 2 ordering under the PerfModel on a mixed 1xH800 + 1xH20
    pool (equal device counts, so the placements differ only by which
    role lands on which chip class)."""
    cfg = get_config(model_id)
    kw = dict(prompt_tokens=PROMPT_TOKENS, new_tokens=NEW_TOKENS,
              concurrency=CONCURRENCY)
    affine = PERF.price_placement(cfg, H800, H20, **kw)
    anti = PERF.price_placement(cfg, H20, H800, **kw)
    homog_h800 = PERF.price_placement(cfg, H800, H800, **kw)
    homog_h20 = PERF.price_placement(cfg, H20, H20, **kw)
    return affine, anti, homog_h800, homog_h20


def live(steps=2):
    """Real pipeline on a ResourceManager-backed heterogeneous pool: the
    deliberately mis-split placement (2 prefill / 1 decode) backlogs the
    decode side, and the rebalancer flips one engine — releasing its H800
    device and re-binding it on the free H20 device."""
    cfg = get_config("tiny")
    model = Model(cfg, remat=False)
    opt = default_optimizer(1e-3)
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    rm = ResourceManager({"H800": 2, "H20": 2})
    # steps_per_dispatch=1: the mis-split decode backlog that triggers the
    # rebalancer builds up per single-token pump; the default K=8
    # macro-step drains the lone decode engine too fast to ever leave the
    # hysteresis band on this tiny workload
    proxy = build_pd_proxy(model, state.params, max_slots=4, max_len=256,
                           n_prefill=2, n_decode=1, resource_manager=rm,
                           rebalancer=RebalancerConfig(),
                           steps_per_dispatch=1)
    with LiveRLRunner(
            RunnerConfig(batch_size=4, group_size=2, mode="rollart",
                         tasks=("math", "game", "swe", "webshop"),
                         max_new_tokens=16, pd_disagg=True,
                         pools={"H800": 2, "H20": 2}, affinity=True,
                         steps_per_dispatch=1),
            proxy, state, jax.jit(make_grpo_train_step(model, opt)),
            ServerlessPlatform(), REWARD_FNS["format_bonus"],
            seq_len=256) as runner:
        hist = runner.run_steps(steps)
    proxy.release_bindings()
    return runner, hist


def run(model="qwen3-8b", steps=2):
    b = Bench("affinity_mapping")
    affine, anti, h800, h20 = modeled(model)
    b.row("affine_cost_norm_tput", fmt(affine["cost_norm_throughput"], 4))
    b.row("anti_affine_cost_norm_tput", fmt(anti["cost_norm_throughput"], 4))
    b.row("homog_h800_cost_norm_tput", fmt(h800["cost_norm_throughput"], 4))
    b.row("homog_h20_cost_norm_tput", fmt(h20["cost_norm_throughput"], 4))
    ratio_anti = (affine["cost_norm_throughput"]
                  / anti["cost_norm_throughput"])
    ratio_homog = (affine["cost_norm_throughput"]
                   / max(h800["cost_norm_throughput"],
                         h20["cost_norm_throughput"]))
    b.row("affine_vs_anti_affine", fmt(ratio_anti), ">=1.2 (Table 2 order)")
    b.row("affine_vs_best_homog", fmt(ratio_homog), ">1.0 (Table 2 order)")
    assert ratio_anti >= 1.2, f"affinity ordering violated: {ratio_anti}"
    assert ratio_homog > 1.0, f"homogeneous beat affine: {ratio_homog}"

    runner, hist = live(steps)
    switches = sum(h.role_switches for h in hist)
    b.row("live_steps_completed", len(hist))
    b.row("live_role_switches", switches, ">=1 (dynamic rebalance)")
    b.row("live_switch_migrations", runner.proxy.switch_migrations)
    for ev in runner.proxy.switch_log:
        b.row("live_switch", f"{ev['engine']}:{ev['from_role']}->"
              f"{ev['to_role']}:{ev['from_pool']}->{ev['to_pool']}")
    assert switches >= 1, "no dynamic role switch recorded in StepMetrics"
    b.save()
    return b


if __name__ == "__main__":
    run()

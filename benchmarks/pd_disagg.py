"""Table 5: prefill/decode disaggregation vs colocation, dense (32B) vs MoE
(30B-A3B). Paper rollout-time speedups: dense 1.03x (1P3D) / 1.05x (2P2D);
MoE 1.11x / 1.21x."""
from benchmarks.common import Bench, fmt
from repro.core.simrl import run_sim

PAPER = {"qwen3-32b": ("1.03", "1.05"),
         "qwen3-moe-30b-a3b": ("1.11", "1.21")}


def run(steps=3):
    b = Bench("pd_disagg_tab5")
    for model, (p1, p2) in PAPER.items():
        common = dict(mode="sync_plus", model=model, batch_size=128,
                      num_steps=steps, tasks=("swe",),
                      reward_serverless=True, async_weight_sync=False)
        m_col = run_sim(gen_pools=(("H800", 16), ("H20", 16)), **common)
        r_col = sum(m_col.rollout_s) / max(len(m_col.rollout_s), 1)
        for name, (h800, h20), target in (
                ("1P3D", (8, 24), p1), ("2P2D", (16, 16), p2)):
            m = run_sim(gen_pools=(("H800", h800), ("H20", h20)),
                        pd_disagg=True, **common)
            r = sum(m.rollout_s) / max(len(m.rollout_s), 1)
            b.row(f"{model}_{name}_speedup_vs_colocate",
                  fmt(r_col / r), f"{target} (Tab 5)")
    b.save()
    return b


if __name__ == "__main__":
    run()

"""Fig. 12 (R3 ablation): dedicated reward GPUs vs serverless offloading.
Paper: utilization 6% -> 88%; rollout time 158s -> 77s (the reclaimed GPUs
double the rollout pool)."""
from benchmarks.common import Bench, fmt
from repro.core.simrl import run_sim


def run(steps=4):
    b = Bench("serverless_fig12")
    common = dict(mode="sync_plus", model="qwen3-8b", batch_size=84,
                  group_size=4, reward_exec_s=(4.0, 12.0),
                  num_steps=steps, tasks=("math",),
                  async_weight_sync=False)
    # local: 4 rollout + 4 dedicated reward GPUs
    m_local = run_sim(gen_pools=(("H800", 4),), reward_serverless=False,
                      reward_gpu_devices=4, **common)
    # serverless: all 8 GPUs roll out; reward scales to zero
    m_sls = run_sim(gen_pools=(("H800", 8),), reward_serverless=True,
                    **common)
    r_local = sum(m_local.rollout_s) / max(len(m_local.rollout_s), 1)
    r_sls = sum(m_sls.rollout_s) / max(len(m_sls.rollout_s), 1)
    b.row("local_rollout_s", fmt(r_local, 1), "158 (Fig 12)")
    b.row("serverless_rollout_s", fmt(r_sls, 1), "77 (Fig 12)")
    b.row("rollout_speedup", fmt(r_local / r_sls), "~2.0 (Fig 12)")
    b.row("dedicated_reward_gpu_util", fmt(m_local.reward_util, 3),
          "0.06-0.074 (Fig 6/12)")
    b.save()
    return b


if __name__ == "__main__":
    run()

"""SLO-burn baseline (ROADMAP item 2): first-class serving SLOs — TTFT
and inter-token gap p50/p99 from the proxy's per-request lifecycle
records — plus the trainer's step-time-budget burn rate.

Two phases:

1. **Serving SLOs.** An open-loop prompt load runs against a tiny live
   engine through :class:`repro.serve.RolloutService` with the obs plane
   attached (``instrument_proxy``), so the same numbers land in the
   ``repro_slo_*`` histograms a Prometheus scrape would see. Percentiles
   are computed exactly from the lifecycle records; the histogram's
   bucket-bound estimate is reported next to the exact p99 as a
   cross-check of the exporter path.
2. **Step budget burn.** A synchronous tiny runner executes real GRPO
   steps; the budget is 1.2x the first post-warmup step's wall time and
   burn = wall / budget per step. A healthy pipeline holds mean burn
   near 1/1.2 with zero violations; regressions in any protocol phase
   (fetch / barrier / train — the new ``StepMetrics`` phase timings,
   also reported) push it past 1.

    PYTHONPATH=src python -m benchmarks.slo_burn [--smoke]
"""
from __future__ import annotations

import argparse
import random
import time

import jax

from benchmarks.common import Bench, fmt, header
from repro.configs import get_config
from repro.core import (EngineHandle, LiveRLRunner, LLMProxy, RunnerConfig,
                        ServerlessPlatform)
from repro.models import Model
from repro.obs import MetricsRegistry, instrument_proxy
from repro.rewards.rule_based import format_bonus_reward
from repro.rl.engine import InferenceEngine
from repro.rl.trainer import (default_optimizer, init_train_state,
                              make_grpo_train_step)
from repro.serve import JobState, RolloutJob, RolloutService

WARMUP = 2


def _pctl(xs, q):
    if not xs:
        return 0.0
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(q * len(xs)))]


def _serving_slos(b: Bench, duration_s: float, rate: float,
                  max_new: int = 24):
    cfg = get_config("tiny")
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(model, params, max_slots=4, max_len=128, seed=0)
    proxy = LLMProxy([EngineHandle(eng, "H20")])
    reg = MetricsRegistry()
    instrument_proxy(reg, proxy)      # fills the repro_slo_* histograms
    svc = RolloutService(proxy, max_inflight=8)
    svc.register_tenant("slo", weight=1.0, max_queue=64)
    rng = random.Random(0)
    tickets = []
    svc.start()
    try:
        t_end = time.monotonic() + duration_s
        next_t = time.monotonic()
        while time.monotonic() < t_end:
            now = time.monotonic()
            while next_t <= now:
                tickets.append(svc.submit("slo", RolloutJob(
                    kind="prompt",
                    prompt=[1, 5, 7, rng.randrange(3, 250)],
                    max_new_tokens=max_new, temperature=1.0,
                    stop_tokens=())))
                next_t += rng.expovariate(rate)
            time.sleep(0.002)
        deadline = time.monotonic() + 30
        while any(not t.done for t in tickets):
            if time.monotonic() > deadline:
                raise RuntimeError("drain did not complete in 30s")
            time.sleep(0.01)
    finally:
        svc.close()
    if svc.error is not None:
        raise RuntimeError("service thread crashed") from svc.error
    done = sum(1 for t in tickets if t.state == JobState.DONE)
    recs = proxy.drain_completed_lifecycles()
    ttft = [r.ttft for r in recs if r.ttft is not None]
    gaps = [g for r in recs for g in r.gaps()]
    reg.collect()                     # one scrape: mirror into families
    hist = {f.name: f for f in reg.families()}
    ttft_hist_p99 = hist["repro_slo_ttft_seconds"].child().percentile(0.99)
    b.row("slo_requests_done", done)
    b.row("ttft_p50_ms", fmt(1e3 * _pctl(ttft, 0.5), 2))
    b.row("ttft_p99_ms", fmt(1e3 * _pctl(ttft, 0.99), 2))
    b.row("ttft_p99_ms_hist_estimate", fmt(1e3 * ttft_hist_p99, 2),
          "same order as ttft_p99_ms (bucket-bound estimator)")
    b.row("intertoken_p50_ms", fmt(1e3 * _pctl(gaps, 0.5), 2))
    b.row("intertoken_p99_ms", fmt(1e3 * _pctl(gaps, 0.99), 2))


def _step_burn(b: Bench, steps: int):
    cfg = get_config("tiny")
    model = Model(cfg, remat=False)
    opt = default_optimizer(1e-3)
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    eng = InferenceEngine(model, state.params, max_slots=8, max_len=256,
                          seed=3)
    proxy = LLMProxy([EngineHandle(eng, "H20")])
    with LiveRLRunner(
            RunnerConfig(batch_size=4, group_size=2, alpha=2, mode="sync",
                         tasks=("game",), max_new_tokens=16,
                         temperature=0.0, seed=0),
            proxy, state,
            jax.jit(make_grpo_train_step(model, opt, num_microbatches=2)),
            ServerlessPlatform(), format_bonus_reward,
            seq_len=256) as runner:
        hist = runner.run_steps(WARMUP + steps)
    warm = hist[WARMUP:]
    budget = 1.2 * warm[0].wall_s
    burns = [s.wall_s / budget for s in warm]
    b.row("step_budget_s", fmt(budget, 4),
          "1.2x first post-warmup step")
    b.row("step_burn_mean", fmt(sum(burns) / len(burns), 3),
          "~0.83 (= 1/1.2) when step time is stable")
    b.row("step_burn_max", fmt(max(burns), 3))
    b.row("step_budget_violations", sum(1 for x in burns if x > 1.0),
          "0")
    for phase in ("fetch_s", "barrier_s", "train_s"):
        vals = [s.to_dict()[phase] for s in warm]
        b.row(f"step_{phase}_mean", fmt(sum(vals) / len(vals), 4))


def run(duration_s: float = 6.0, rate: float = 60.0, steps: int = 6,
        smoke: bool = False, save: bool = True):
    if smoke:
        duration_s, rate, steps = 1.5, 30.0, 3
    b = Bench("slo_burn")
    _serving_slos(b, duration_s, rate)
    _step_burn(b, steps)
    if save:
        b.save()
    return b


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short window for CI (no JSON rewrite)")
    args = ap.parse_args(argv)
    if args.smoke:
        header()
    run(smoke=args.smoke, save=not args.smoke)


if __name__ == "__main__":
    main()

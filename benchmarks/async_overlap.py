"""Train/rollout overlap, live engines: decode tokens generated DURING
train_step and mean step wall-time, threaded runner (rollart / one_off)
vs the synchronous baseline on the same seed/workload.

Expected shape (the tentpole's acceptance criteria): the synchronous
runner accrues ZERO decode tokens while train_step runs (nothing pumps the
engines), the threaded modes accrue > 0, and the threaded mean step time
is below sync's because batch collection overlaps training instead of
strictly alternating with it. one_off additionally shows the previous-
batch rule: every trained batch left the buffer on an earlier step.

    PYTHONPATH=src python -m benchmarks.async_overlap
"""
import jax

from benchmarks.common import Bench, fmt
from repro.configs import get_config
from repro.core import (EngineHandle, LiveRLRunner, LLMProxy, RunnerConfig,
                        ServerlessPlatform)
from repro.core.serverless import ServerlessConfig
from repro.models import Model
from repro.rewards.rule_based import format_bonus_reward
from repro.rl.engine import InferenceEngine
from repro.rl.trainer import (default_optimizer, init_train_state,
                              make_grpo_train_step)

WARMUP = 2      # steps paying one-time jit compilation, dropped from means


def _run_mode(mode: str, steps: int, seed: int = 0):
    """Fresh model/engine/runner per mode: identical workload, identical
    seeds, identical serverless latency model (the paper's measured reward
    I/O tax, actually slept) — only the coordination differs."""
    cfg = get_config("tiny")
    model = Model(cfg, remat=False)
    opt = default_optimizer(1e-3)
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    eng = InferenceEngine(model, state.params, max_slots=8, max_len=256,
                          seed=3)
    proxy = LLMProxy([EngineHandle(eng, "H20")])
    sls = ServerlessPlatform(
        ServerlessConfig(sleep_io=True, io_mean_s=0.03, io_tail_prob=0.0),
        seed=seed)
    with LiveRLRunner(
            RunnerConfig(batch_size=8, group_size=4, alpha=2, mode=mode,
                         tasks=("game",), max_new_tokens=16,
                         temperature=0.0, seed=seed),
            proxy, state,
            jax.jit(make_grpo_train_step(model, opt, num_microbatches=2)),
            sls, format_bonus_reward, seq_len=256) as runner:
        hist = runner.run_steps(steps)
    return hist


def _mean_warm(h):
    warm = h[WARMUP:] or h
    return sum(s.wall_s for s in warm) / len(warm)


def run(steps: int = 8):
    b = Bench("async_overlap")
    hist = {m: _run_mode(m, steps) for m in ("sync", "rollart", "one_off")}
    for mode, h in hist.items():
        b.row(f"{mode}_decode_toks_during_train",
              sum(s.decode_during_train for s in h),
              "0 in sync, > 0 in threaded modes")
        b.row(f"{mode}_mean_step_s", fmt(_mean_warm(h), 3))
    b.row("rollart_vs_sync_step_speedup",
          fmt(_mean_warm(hist["sync"]) / _mean_warm(hist["rollart"]), 2),
          "> 1 (rollout + reward I/O overlap training)")
    one_off_prev = all(s.batch_fetched_step < s.step
                       for s in hist["one_off"])
    b.row("one_off_trains_on_previous_batch", one_off_prev, "True")
    b.save()
    return b


if __name__ == "__main__":
    run()

"""Shared helpers for the benchmark suite: CSV emission + result storage."""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "bench")


class Bench:
    """Collects (name, value, derived/paper-target) rows, prints CSV."""

    def __init__(self, name: str):
        self.name = name
        self.rows: List[Dict] = []
        self.t0 = time.time()

    def row(self, metric: str, value, target: str = ""):
        self.rows.append({"bench": self.name, "metric": metric,
                          "value": value, "target": target})
        print(f"{self.name},{metric},{value},{target}", flush=True)

    def save(self):
        os.makedirs(RESULTS_DIR, exist_ok=True)
        path = os.path.join(RESULTS_DIR, f"{self.name}.json")
        with open(path, "w") as f:
            json.dump({"rows": self.rows,
                       "wall_s": time.time() - self.t0}, f, indent=1,
                      default=str)
        return path


def header():
    print("bench,metric,value,paper_target", flush=True)


def fmt(x, nd=2):
    return round(float(x), nd)

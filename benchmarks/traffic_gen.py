"""Rollout-as-a-Service under multi-tenant load (ROADMAP item 1): an
open-loop traffic generator driving two weighted tenants against live
engines through :class:`repro.serve.RolloutService`.

Open-loop means arrivals follow a fixed schedule (Poisson inter-arrival
times) regardless of completions — the honest way to measure a serving
tier, since closed-loop generators self-throttle and hide queueing
collapse. The aggregate arrival rate is set well above engine capacity,
so the run measures behavior *under overload*:

- goodput (completed jobs/s and streamed tokens/s) per tenant,
- time-to-first-token and inter-token latency p50/p99 from the proxy's
  per-request lifecycle records (``LLMProxy.drain_completed_lifecycles``
  — the data plane stamps submit/admit/first-token/finish itself, so no
  client-side recomputation from chunk arrival times),
- fairness: the measured per-tenant admission/completion share against
  the configured stride weights (gold:bronze = 3:1 -> 0.75 share), and
- backpressure: submissions rejected by the bounded per-tenant queues.

    PYTHONPATH=src python -m benchmarks.traffic_gen [--smoke]
"""
from __future__ import annotations

import argparse
import random
import time

import jax

from benchmarks.common import Bench, fmt, header
from repro.configs import get_config
from repro.core import EngineHandle, LLMProxy
from repro.models import Model
from repro.rl.engine import InferenceEngine
from repro.serve import JobState, RolloutJob, RolloutService

TENANTS = {"gold": 3.0, "bronze": 1.0}


def _pctl(xs, q):
    if not xs:
        return 0.0
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(q * len(xs)))]


def _submit_open_loop(svc, rng, duration_s, rate_per_tenant,
                      max_new, max_queue_stats):
    """Fixed-schedule arrivals for every tenant until the window closes;
    returns the per-tenant ticket lists and the window close time."""
    tickets = {name: [] for name in TENANTS}
    next_t = {name: time.monotonic() for name in TENANTS}
    t_end = time.monotonic() + duration_s
    while time.monotonic() < t_end:
        now = time.monotonic()
        for name in TENANTS:
            while next_t[name] <= now:
                job = RolloutJob(
                    kind="prompt",
                    prompt=[1, 5, 7, rng.randrange(3, 250)],
                    max_new_tokens=max_new, temperature=1.0,
                    stop_tokens=())
                tickets[name].append(svc.submit(name, job))
                next_t[name] += rng.expovariate(rate_per_tenant)
        time.sleep(0.002)
    return tickets, time.monotonic()


def run(duration_s: float = 8.0, rate_per_tenant: float = 150.0,
        max_new: int = 32, max_slots: int = 4, smoke: bool = False,
        save: bool = True):
    # EVERY tenant's offered load must exceed its fair share of capacity
    # (tiny engine, warm: ~100 jobs/s total -> gold's share ~75 jobs/s),
    # or work-conserving fairness redistributes the under-user's slack
    # and the measured split trivially tracks offered load instead of the
    # weights. 150 jobs/s per tenant keeps both backlogged throughout.
    if smoke:
        duration_s, rate_per_tenant = 2.0, 50.0
    b = Bench("traffic_gen")
    cfg = get_config("tiny")
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(model, params, max_slots=max_slots, max_len=128,
                          seed=0)
    # admission window ~ engine capacity: overload queues at the service
    # where the stride scheduler arbitrates shares
    svc = RolloutService(LLMProxy([EngineHandle(eng, "H20")]),
                         max_inflight=2 * max_slots)
    for name, w in TENANTS.items():
        svc.register_tenant(name, weight=w, max_queue=64)
    rng = random.Random(0)
    svc.start()
    try:
        tickets, t_close = _submit_open_loop(
            svc, rng, duration_s, rate_per_tenant, max_new, b)
        # snapshot the stride bookkeeping at window close: admissions up
        # to here all happened under sustained overload
        congested = svc.stats()
        # stop the offered load, abort the backlog, let in-flight finish
        for name, ts in tickets.items():
            for t in ts:
                if not t.done and t.state != JobState.RUNNING:
                    svc.abort_job(t)
        deadline = time.monotonic() + 30
        while any(not t.done for ts in tickets.values() for t in ts):
            if time.monotonic() > deadline:
                raise RuntimeError("drain did not complete in 30s")
            time.sleep(0.01)
    finally:
        svc.close()
    if svc.error is not None:
        raise RuntimeError("service thread crashed") from svc.error

    adm_total = sum(congested[n]["admitted"] for n in TENANTS)
    w_total = sum(TENANTS.values())
    # SLO timings come from the proxy's own lifecycle records: TTFT is
    # proxy-submit -> first GROWING stream delivery (admission queueing
    # inside the service is excluded — it's reported separately via the
    # rejected/admitted rows), gaps are per-token
    lcs = {lc.request_id: lc
           for lc in svc.proxy.drain_completed_lifecycles()}
    ttft, gaps = {}, {}
    for name, ts in tickets.items():
        done = [t for t in ts if t.state == JobState.DONE]
        recs = [lcs[f"{t.job_id}.r0"] for t in done
                if f"{t.job_id}.r0" in lcs]
        ttft[name] = [r.ttft for r in recs if r.ttft is not None]
        gaps[name] = [g for r in recs for g in r.gaps()]
    for name in TENANTS:
        ts = tickets[name]
        done = [t for t in ts if t.state == JobState.DONE]
        tokens = sum(len(t.results[0].tokens) for t in done if t.results)
        share = congested[name]["admitted"] / max(adm_total, 1)
        target = TENANTS[name] / w_total
        b.row(f"{name}_offered_jobs", len(ts))
        b.row(f"{name}_completed_jobs", len(done))
        b.row(f"{name}_rejected_jobs", congested[name]["rejected"],
              "bounded-queue backpressure")
        b.row(f"{name}_goodput_tok_s", fmt(tokens / duration_s, 1))
        b.row(f"{name}_admitted_share", fmt(share, 3),
              f"{target:.2f} (weight {TENANTS[name]:g}/{w_total:g})")
        b.row(f"{name}_ttft_p50_ms", fmt(1e3 * _pctl(ttft[name], 0.5), 1))
        b.row(f"{name}_ttft_p99_ms", fmt(1e3 * _pctl(ttft[name], 0.99), 1))
        b.row(f"{name}_tok_gap_p50_ms",
              fmt(1e3 * _pctl(gaps[name], 0.5), 1))
        b.row(f"{name}_tok_gap_p99_ms",
              fmt(1e3 * _pctl(gaps[name], 0.99), 1))
    gold_share = congested["gold"]["admitted"] / max(adm_total, 1)
    b.row("fairness_gold_share_error", fmt(abs(gold_share - 0.75), 3),
          "~0 (stride QoS tracks weights under overload)")
    if not smoke and adm_total >= 20:
        assert abs(gold_share - 0.75) < 0.15, \
            f"measured gold share {gold_share:.2f} far from weight 0.75"
    if save:
        b.save()
    return b


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short window for CI (no JSON rewrite)")
    ap.add_argument("--duration", type=float, default=8.0)
    args = ap.parse_args(argv)
    if args.smoke:
        header()
    run(duration_s=args.duration, smoke=args.smoke, save=not args.smoke)


if __name__ == "__main__":
    main()

"""Decode hot-path microbenchmark: the tentpole evidence for the
device-resident decode loop (multi-token dispatch + donated KV caches +
bucketed in-place prefill admission). Three engine variants serve the
same greedy workloads on the tiny config (XLA:CPU):

  seed_single_undonated — steps_per_dispatch=1, un-donated cache (every
                          decode step copies the full KV cache) and
                          one-compile-per-prompt-length admission: the
                          seed engine's hot path
  single_donated        — K=1 with donated caches + bucketed admission
  block_donated         — K scanned decode steps per jit dispatch on top
                          (the default hot path)

Three phases:
  cold-lengths serving (HEADLINE) — the measured request set carries
      prompt lengths the engine has not seen. Bucketed variants reuse
      their O(log max_len) compiled shapes; the seed baseline recompiles
      prefill per fresh length (~0.8 s each on tiny), exactly as it did
      in live training whenever the env produced a new prompt length.
  warm decode — all shapes compiled, variants measured in interleaved
      rounds (median) to factor out machine drift: isolates the K-fold
      dispatch amortization, which on a 2-core CPU is bounded by XLA's
      per-op execution cost rather than dispatch overhead.
  single stream — one active slot, so dispatches/token == 1/K exactly.

Greedy parity across variants is asserted alongside the speedups, so the
fast path provably emits the same tokens it accelerates.
"""
import time

import jax
import numpy as np

from benchmarks.common import Bench, fmt
from repro.configs import get_config
from repro.models import Model
from repro.rl.engine import GenRequest, InferenceEngine

VARIANTS = (
    ("seed_single_undonated",
     dict(steps_per_dispatch=1, donate=False, bucketed_prefill=False)),
    ("single_donated", dict(steps_per_dispatch=1, donate=True)),
    ("block_donated", None),        # filled with the requested K
)


def _serve(eng, prompts, tag, max_new, out=None):
    for i, p in enumerate(prompts):
        eng.add_request(GenRequest(
            request_id=f"{tag}{i}", prompt=p, max_new_tokens=max_new,
            temperature=0.0))
    eng.run_until_idle()
    if out is not None:
        for i in range(len(prompts)):
            out.append(eng.pop_result(f"{tag}{i}").tokens)


def _tps(eng, prompts, tag, max_new, out=None):
    d0 = eng.decode_tokens
    t0 = time.perf_counter()
    _serve(eng, prompts, tag, max_new, out=out)
    return (eng.decode_tokens - d0) / (time.perf_counter() - t0)


def run(n_requests=16, max_new=96, steps_per_dispatch=8, slots=8, reps=5,
        cold_lengths=8, save=True):
    b = Bench("decode_hotpath")
    cfg = get_config("tiny")
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)

    def prompt(n):
        return [1] + list(rng.randint(3, cfg.vocab_size - 1, size=n - 1))

    # warm set: lengths 4..14 plus one > 16 so both power-of-two buckets
    # (16 and 32) are compiled for the bucketed variants
    warm_prompts = [prompt(int(rng.randint(4, 15)))
                    for _ in range(n_requests)] + [prompt(20)]
    # cold set: previously-unseen exact lengths (same buckets)
    cold_prompts = [prompt(21 + 2 * j) for j in range(cold_lengths)]

    engines, cold_tps, streams = {}, {}, {}
    for name, kw in VARIANTS:
        if kw is None:
            kw = dict(steps_per_dispatch=steps_per_dispatch, donate=True)
        eng = InferenceEngine(model, params, max_slots=slots, max_len=256,
                              seed=1, **kw)
        streams[name] = []
        _serve(eng, warm_prompts, "warm", max_new, out=streams[name])
        # HEADLINE: serving throughput when fresh prompt lengths arrive
        cold_tps[name] = _tps(eng, cold_prompts, "cold", max_new,
                              out=streams[name])
        engines[name] = eng

    # warm-decode phase: interleaved rounds, median per variant
    warm_tps = {name: [] for name in engines}
    for rnd in range(reps):
        for name, eng in engines.items():
            warm_tps[name].append(
                _tps(eng, warm_prompts, f"m{rnd}", max_new))
    warm_med = {n: sorted(v)[len(v) // 2] for n, v in warm_tps.items()}

    # single-stream phase: dispatches/token == 1/K exactly
    disp_per_tok = {}
    for name, eng in engines.items():
        d0, p0 = eng.decode_tokens, eng.decode_dispatches
        _serve(eng, warm_prompts[:1], "ss", max_new)
        disp_per_tok[name] = ((eng.decode_dispatches - p0)
                              / (eng.decode_tokens - d0))

    base = "seed_single_undonated"
    parity = int(all(s == streams[base] for s in streams.values()))
    b.row("greedy_parity", parity, "1 (identical across variants)")
    assert parity, "fast-path variants diverged from the seed token stream"
    for name in engines:
        b.row(f"cold_serving_tokens_per_s_{name}", fmt(cold_tps[name], 1))
    b.row("speedup_block_donated_cold",
          fmt(cold_tps["block_donated"] / cold_tps[base], 2), ">=2.0")
    for name in engines:
        b.row(f"warm_decode_tokens_per_s_{name}", fmt(warm_med[name], 1))
    b.row("speedup_block_donated_warm",
          fmt(warm_med["block_donated"] / warm_med[base], 2))
    b.row("block_dispatches_per_token",
          fmt(disp_per_tok["block_donated"], 4),
          f"~{fmt(1.0 / steps_per_dispatch, 4)} (1/K)")
    b.row("single_dispatches_per_token", fmt(disp_per_tok[base], 4), "1.0")
    b.row("prefill_compiles_seed",
          _prefill_compiles(engines[base]),
          "one per distinct prompt length")
    b.row("prefill_compiles_bucketed",
          _prefill_compiles(engines["block_donated"]),
          "O(log max_len) buckets")
    b.row("steps_per_dispatch", steps_per_dispatch)
    if save:
        b.save()
    return b


def _prefill_compiles(eng):
    f = eng._prefill_jit
    return f._cache_size() if hasattr(f, "_cache_size") else -1


if __name__ == "__main__":
    run()

"""Per-kernel microbenchmark: us_per_call of the Pallas kernels (interpret
mode on CPU — correctness-path timing, NOT TPU perf) vs the jnp oracle."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Bench, fmt
from repro.kernels import ref as R
from repro.kernels.decode_attention import decode_attention, \
    ragged_paged_decode
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.rwkv6_scan import rwkv6_scan


def timeit(fn, *args, n=3):
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def run():
    b = Bench("kernels")
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 4, 128, 64))
    k = jax.random.normal(key, (1, 2, 128, 64))
    v = jax.random.normal(key, (1, 2, 128, 64))
    b.row("flash_attention_us", fmt(timeit(
        lambda *a: flash_attention(*a, block_q=64, block_k=64), q, k, v), 0))
    b.row("flash_ref_us", fmt(timeit(R.flash_ref, q, k, v), 0))

    qd = jax.random.normal(key, (2, 4, 64))
    kc = jax.random.normal(key, (2, 2, 256, 64))
    lens = jnp.asarray([128, 256])
    b.row("decode_attention_us", fmt(timeit(
        lambda *a: decode_attention(*a, block_k=128), qd, kc, kc, lens), 0))
    b.row("decode_ref_us", fmt(timeit(R.decode_ref, qd, kc, kc, lens), 0))

    # ragged paged decode at 1-of-4 occupancy: one 64-token row, three
    # inactive. The page-table walk skips pages at/after each row's
    # length, so KV bytes streamed scale with ceil(len/page) pages per
    # row; the dense kernel streams the whole B x S cache slab. The
    # bytes-touched roofline rows quantify that gap (the us timings here
    # are interpret-mode correctness-path numbers, not TPU perf).
    page, P, B4, kvH, hd = 16, 16, 4, 2, 64
    q4 = jax.random.normal(key, (B4, 4, hd))
    pool_k = jax.random.normal(key, (B4 * P + 1, kvH, page, hd))
    pool_v = jax.random.normal(key, (B4 * P + 1, kvH, page, hd))
    tables = jnp.arange(B4 * P, dtype=jnp.int32).reshape(B4, P)
    lens4 = jnp.asarray([64, 0, 0, 0], jnp.int32)
    b.row("ragged_paged_decode_us", fmt(timeit(
        lambda *a: ragged_paged_decode(*a), q4, pool_k, pool_v, tables,
        lens4), 0))
    gk = jnp.moveaxis(pool_k[tables], 2, 1).reshape(B4, kvH, P * page, hd)
    gv = jnp.moveaxis(pool_v[tables], 2, 1).reshape(B4, kvH, P * page, hd)
    b.row("ragged_gathered_ref_us", fmt(timeit(
        R.decode_ref, q4, gk, gv, lens4), 0))
    np.testing.assert_allclose(
        np.asarray(ragged_paged_decode(q4, pool_k, pool_v, tables, lens4))[0],
        np.asarray(R.decode_ref(q4, gk, gv, lens4))[0],
        rtol=2e-5, atol=2e-5)
    kv_elt = 2 * kvH * page * hd * 4          # k+v page pair, fp32 bytes
    dense_bytes = B4 * P * kv_elt             # full slab, every call
    ragged_bytes = int(sum(-(-int(n) // page) for n in lens4)) * kv_elt
    b.row("roofline_decode_kv_bytes_dense", dense_bytes)
    b.row("roofline_decode_kv_bytes_ragged", ragged_bytes)
    b.row("roofline_decode_kv_bytes_frac",
          fmt(ragged_bytes / dense_bytes, 3), "<1.0")

    r = jax.random.normal(key, (1, 64, 2, 32))
    lw = jnp.clip(-jnp.exp(jax.random.normal(key, (1, 64, 2, 32))),
                  -2.5, -1e-4)
    u = jnp.zeros((2, 32))
    b.row("rwkv6_scan_us", fmt(timeit(
        lambda *a: rwkv6_scan(*a, chunk=32)[0], r, r, r, lw, u), 0))
    b.row("rwkv6_ref_us", fmt(timeit(
        lambda *a: R.rwkv6_ref(*a)[0], r, r, r, lw, u), 0))

    x = jax.random.normal(key, (1, 64, 128))
    dt = jax.nn.softplus(jax.random.normal(key, (1, 64, 128)) - 2)
    Bm = jax.random.normal(key, (1, 64, 16))
    A_log = jnp.zeros((128, 16))
    D = jnp.ones((128,))
    b.row("mamba_scan_us", fmt(timeit(
        lambda *a: mamba_scan(*a, chunk=32, block_d=128)[0],
        x, dt, Bm, Bm, A_log, D), 0))
    b.row("mamba_ref_us", fmt(timeit(
        lambda *a: R.mamba_ref(*a)[0], x, dt, Bm, Bm, A_log, D), 0))
    b.save()
    return b


if __name__ == "__main__":
    run()

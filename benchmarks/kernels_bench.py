"""Per-kernel microbenchmark: us_per_call of the Pallas kernels (interpret
mode on CPU — correctness-path timing, NOT TPU perf) vs the jnp oracle."""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import Bench, fmt
from repro.kernels import ref as R
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.rwkv6_scan import rwkv6_scan


def timeit(fn, *args, n=3):
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def run():
    b = Bench("kernels")
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 4, 128, 64))
    k = jax.random.normal(key, (1, 2, 128, 64))
    v = jax.random.normal(key, (1, 2, 128, 64))
    b.row("flash_attention_us", fmt(timeit(
        lambda *a: flash_attention(*a, block_q=64, block_k=64), q, k, v), 0))
    b.row("flash_ref_us", fmt(timeit(R.flash_ref, q, k, v), 0))

    qd = jax.random.normal(key, (2, 4, 64))
    kc = jax.random.normal(key, (2, 2, 256, 64))
    lens = jnp.asarray([128, 256])
    b.row("decode_attention_us", fmt(timeit(
        lambda *a: decode_attention(*a, block_k=128), qd, kc, kc, lens), 0))
    b.row("decode_ref_us", fmt(timeit(R.decode_ref, qd, kc, kc, lens), 0))

    r = jax.random.normal(key, (1, 64, 2, 32))
    lw = jnp.clip(-jnp.exp(jax.random.normal(key, (1, 64, 2, 32))),
                  -2.5, -1e-4)
    u = jnp.zeros((2, 32))
    b.row("rwkv6_scan_us", fmt(timeit(
        lambda *a: rwkv6_scan(*a, chunk=32)[0], r, r, r, lw, u), 0))
    b.row("rwkv6_ref_us", fmt(timeit(
        lambda *a: R.rwkv6_ref(*a)[0], r, r, r, lw, u), 0))

    x = jax.random.normal(key, (1, 64, 128))
    dt = jax.nn.softplus(jax.random.normal(key, (1, 64, 128)) - 2)
    Bm = jax.random.normal(key, (1, 64, 16))
    A_log = jnp.zeros((128, 16))
    D = jnp.ones((128,))
    b.row("mamba_scan_us", fmt(timeit(
        lambda *a: mamba_scan(*a, chunk=32, block_d=128)[0],
        x, dt, Bm, Bm, A_log, D), 0))
    b.row("mamba_ref_us", fmt(timeit(
        lambda *a: R.mamba_ref(*a)[0], x, dt, Bm, Bm, A_log, D), 0))
    b.save()
    return b


if __name__ == "__main__":
    run()

"""Fault-tolerance benchmark (paper §8): recovery time and lost-work
tokens of rollout-level checkpoint/restore vs a restart-from-scratch
baseline, on the live engines.

Two experiments:

1. **Trainer failure** (sync mode, greedy, deterministic): train to step
   K with paired train+rollout checkpoints at every barrier, kill the
   trainer, and compare the two restart strategies' cost of getting back
   to the kill frontier — decode tokens regenerated and wall clock.
   Scratch restarts from step 0 and regenerates every trajectory;
   snapshot restore re-buffers the snapshot's samples and re-injects
   in-flight KV, so only the last partial step redoes work. The restored
   run then continues to step S and must train byte-identical
   trajectory streams to an uninterrupted reference (greedy parity), and
   no ``traj_id`` may train twice across the surviving lineage.

2. **Injected plane failures** (rollart mode, threaded): a deterministic
   schedule of engine / env / whole-rollout-plane failures (the paper's
   ~1-in-10-iteration env failure class) runs once under supervised
   snapshot recovery and once under the scratch policy; per-event
   destroyed vs recovered token accounting is reported, and the
   rollout-plane restore exercises the buffer's traj_id dedup.

    PYTHONPATH=src python -m benchmarks.fault_tolerance [--smoke]
"""
from __future__ import annotations

import argparse
import shutil
import tempfile
import time

import jax

from benchmarks.common import Bench, fmt
from repro.configs import get_config
from repro.core import (EngineHandle, LiveRLRunner, LLMProxy, RunnerConfig,
                        ServerlessPlatform)
from repro.ft import (FTConfig, FTSupervisor, FailureInjector,
                      restore_latest)
from repro.models import Model
from repro.rewards.rule_based import REWARD_FNS
from repro.rl.engine import InferenceEngine
from repro.rl.trainer import (default_optimizer, init_train_state,
                              make_grpo_train_step)


def _fresh_state():
    cfg = get_config("tiny")
    model = Model(cfg, remat=False)
    return init_train_state(model, jax.random.PRNGKey(0),
                            default_optimizer(1e-3))


def _runner_factory(mode: str, tasks=("game",), max_new: int = 16,
                    max_len: int = 320, seed: int = 0):
    """make_runner(state) closures with identical seeds/workload — the
    shape ``restore_latest`` needs for the trainer-restart path."""
    def make(state):
        cfg = get_config("tiny")
        model = Model(cfg, remat=False)
        opt = default_optimizer(1e-3)
        eng = InferenceEngine(model, state.params, max_slots=8,
                              max_len=max_len, seed=3)
        proxy = LLMProxy([EngineHandle(eng, "local")])
        return LiveRLRunner(
            RunnerConfig(batch_size=4, group_size=2, alpha=2, mode=mode,
                         tasks=tasks, max_new_tokens=max_new,
                         temperature=0.0, seed=seed),
            proxy, state, jax.jit(make_grpo_train_step(model, opt)),
            ServerlessPlatform(), REWARD_FNS["format_bonus"],
            seq_len=max_len)
    return make


def _tap_stream(runner):
    """Record the exact (tokens, reward) content of every trained batch —
    the id-free stream the greedy-parity check compares."""
    runner._ft_stream = []
    orig = runner._pack

    def pack(trajs):
        runner._ft_stream.append(
            [(tuple(t.tokens), round(float(t.reward), 6)) for t in trajs])
        return orig(trajs)
    runner._pack = pack


# ---------------------------------------------------------------------------
# experiment 1: trainer failure — snapshot restore vs restart-from-scratch
# ---------------------------------------------------------------------------
def _trainer_failure(b: Bench, total_steps: int, kill_at: int):
    make = _runner_factory("sync")
    # uninterrupted reference
    ref = make(_fresh_state())
    _tap_stream(ref)
    with ref:
        ref.run_steps(total_steps)
    ref_stream = ref._ft_stream
    ref_ids = [i for batch in ref.trained_log for i in batch]
    assert len(ref_ids) == len(set(ref_ids))

    ckpt = tempfile.mkdtemp(prefix="ft_bench_")
    try:
        # run to the kill point with paired checkpoints at every barrier
        victim = make(_fresh_state())
        sup = FTSupervisor(victim, FTConfig(snapshot_every=1,
                                            keep_last=kill_at + 1),
                           ckpt_dir=ckpt)
        sup.run_steps(kill_at)
        sup.snapshotter.wait()
        pre_kill_ids = [i for batch in victim.trained_log[:kill_at - 1]
                        for i in batch]
        victim.close()            # the trainer "dies" here
        sup.close()

        # strategy A — restart from scratch: regenerate everything back
        # to the kill frontier
        t0 = time.monotonic()
        scratch = make(_fresh_state())
        with scratch:
            scratch.run_steps(kill_at)
        scratch_wall = time.monotonic() - t0
        scratch_tokens = scratch._decode_tokens_total()

        # strategy B — restore the latest paired checkpoint (the barrier
        # of step kill_at-1) and redo only that step
        t0 = time.monotonic()
        restored, start = restore_latest(ckpt, _fresh_state(), make)
        _tap_stream(restored)
        with restored:
            restored.run_steps(1)
            snap_wall = time.monotonic() - t0
            snap_tokens = restored._decode_tokens_total()
            # continue to the reference horizon for the parity check
            restored.run_steps(total_steps - start - 1)
        got = restored._ft_stream
        want = ref_stream[start:]
        parity = (len(got) == len(want)
                  and all(g == w for g, w in zip(got, want)))
        lineage = pre_kill_ids + [i for batch in restored.trained_log
                                  for i in batch]
        no_double_train = len(lineage) == len(set(lineage))

        b.row("trainer_kill_step", kill_at)
        b.row("trainer_restore_step", start)
        b.row("trainer_redo_tokens_scratch", scratch_tokens,
              "all pre-kill rollout work regenerated")
        b.row("trainer_redo_tokens_snapshot", snap_tokens,
              "< scratch (buffered + in-flight work survives)")
        b.row("trainer_redo_wall_s_scratch", fmt(scratch_wall, 2))
        b.row("trainer_redo_wall_s_snapshot", fmt(snap_wall, 2),
              "< scratch")
        b.row("trainer_token_savings_x",
              fmt(scratch_tokens / max(1, snap_tokens), 2), "> 1")
        b.row("trainer_wall_savings_x",
              fmt(scratch_wall / max(1e-9, snap_wall), 2), "> 1")
        b.row("greedy_parity_after_restore", parity, "True")
        b.row("no_traj_trained_twice", no_double_train, "True")
        assert parity, "restored run diverged from the uninterrupted one"
        assert no_double_train, "a traj_id trained twice across the kill"
        assert snap_tokens < scratch_tokens
        assert snap_wall < scratch_wall
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


# ---------------------------------------------------------------------------
# experiment 2: injected env/engine/plane failures — supervised recovery
# ---------------------------------------------------------------------------
def _injected_run(schedule, steps: int, scratch: bool):
    # multi-turn but compact observations (the calculator-tool math env):
    # trajectories span several proxy round-trips, so faults land on a
    # plane with real in-flight work
    make = _runner_factory("rollart", tasks=("math",), max_new=24,
                           max_len=512)
    runner = make(_fresh_state())
    sup = FTSupervisor(
        runner, FTConfig(snapshot_every=1, scratch_recovery=scratch),
        injector=FailureInjector(schedule=schedule, seed=11))
    t0 = time.monotonic()
    with runner:
        sup.run_steps(steps)
    sup.close()
    wall = time.monotonic() - t0
    return runner, sup, wall


def _injected_failures(b: Bench, steps: int, schedule):
    runner_s, sup_s, wall_s = _injected_run(schedule, steps, scratch=False)
    runner_x, sup_x, wall_x = _injected_run(schedule, steps, scratch=True)

    # within-run comparison: the same faults, under the scratch policy,
    # would have lost everything they destroyed (the two RUNS cannot be
    # compared token-for-token — threaded timing diverges after the
    # first recovery — so the scratch run only contributes its own
    # lost-work total and wall clock as context)
    destroyed_s = sum(e.destroyed_tokens for e in sup_s.events)
    recovered_s = sum(e.recovered_tokens for e in sup_s.events)
    lost_s = sum(e.lost_tokens for e in sup_s.events)
    lost_x = sum(e.destroyed_tokens for e in sup_x.events)
    b.row("injected_events", len(sup_s.events),
          f"schedule {sorted(schedule.items())}")
    b.row("injected_destroyed_tokens", destroyed_s,
          "in-flight work killed by the faults")
    b.row("injected_recovered_tokens", recovered_s,
          "> 0 (resurrected from snapshots)")
    b.row("injected_lost_tokens_snapshot", lost_s,
          "< destroyed (same faults under scratch lose all of it)")
    b.row("injected_lost_tokens_scratch_run", lost_x,
          "the scratch-policy run's own lost-work total")
    b.row("injected_mean_recovery_s",
          fmt(sum(e.recovery_s for e in sup_s.events)
              / max(1, len(sup_s.events)), 3))
    b.row("injected_wall_s_snapshot", fmt(wall_s, 1))
    b.row("injected_wall_s_scratch", fmt(wall_x, 1))
    b.row("injected_dedup_drops", runner_s.buffer.total_deduped,
          ">= 0 (replayed trajs dropped, never trained twice)")
    ids = [i for batch in runner_s.trained_log for i in batch]
    ids_x = [i for batch in runner_x.trained_log for i in batch]
    b.row("injected_no_traj_trained_twice",
          len(ids) == len(set(ids)) and len(ids_x) == len(set(ids_x)),
          "True")
    assert len(sup_s.events) == len(schedule)
    assert all(e.recovered for e in sup_s.events)
    assert recovered_s > 0, "no event found snapshot-covered work"
    assert lost_s < destroyed_s
    assert len(ids) == len(set(ids)) and len(ids_x) == len(set(ids_x))


def run(smoke: bool = False, save: bool = True):
    b = Bench("fault_tolerance")
    if smoke:
        # CI smoke: one injected engine failure + supervised recovery
        _injected_failures(b, steps=3, schedule={1: "engine"})
    else:
        _trainer_failure(b, total_steps=5, kill_at=3)
        _injected_failures(b, steps=8,
                           schedule={2: "engine", 4: "env", 6: "rollout"})
    if save:
        b.save()
    return b


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one injected engine failure + recovery (CI)")
    args = ap.parse_args(argv)
    run(smoke=args.smoke, save=not args.smoke)


if __name__ == "__main__":
    main()

"""Fig. 11a (R1 ablation): equal-cost rollout configs — 72 H800 vs 208 H20
vs mixed 64 H800 + 24 H20 with task-affinity routing, training fixed on
32 H800. Paper: mixed is 1.30-1.68x faster than H20-only and 1.12-1.37x
faster than H800-only."""
from benchmarks.common import Bench, fmt
from repro.core.simrl import run_sim


def run(model="qwen3-14b", steps=4):
    b = Bench("hw_affinity_fig11a")
    common = dict(mode="rollart", model=model, batch_size=256,
                  num_steps=steps, reward_serverless=True,
                  async_weight_sync=True, prefix_cache=0.4)
    m_h800 = run_sim(gen_pools=(("H800", 72),), **common)
    m_h20 = run_sim(gen_pools=(("H20", 208),), **common)
    m_mix = run_sim(gen_pools=(("H800", 64), ("H20", 24)),
                    hw_affinity={"math": "H20", "game": "H20",
                                 "default": "H800"}, **common)
    b.row("h800_only_step_s", fmt(m_h800.avg_step_s, 1))
    b.row("h20_only_step_s", fmt(m_h20.avg_step_s, 1))
    b.row("mixed_step_s", fmt(m_mix.avg_step_s, 1))
    b.row("mixed_vs_h20_only", fmt(m_h20.avg_step_s / m_mix.avg_step_s),
          "1.30-1.68 (Fig 11a)")
    b.row("mixed_vs_h800_only", fmt(m_h800.avg_step_s / m_mix.avg_step_s),
          "1.12-1.37 (Fig 11a)")
    b.save()
    return b


if __name__ == "__main__":
    run()

"""Fig. 3: breakdown of a (synchronous) training step, success path vs
env-failure path. Paper (Qwen3-8B/32k, SWE, batch 128 on 32 H800):
successful avg 366s with generation only 54%, training 23%, env init 15%;
failures spike the average to 513s with env.reset dominating."""
from benchmarks.common import Bench, fmt
from repro.core.simrl import run_sim
from repro.envs import SWEEnv


def run(steps=5):
    b = Bench("step_breakdown_fig3")
    common = dict(mode="sync", model="qwen3-8b", batch_size=128,
                  num_steps=steps, tasks=("swe",),
                  gen_pools=(("H800", 28),), reward_serverless=False,
                  async_weight_sync=False)
    m_ok = run_sim(env_latency_scale=1.0, **common)
    b.row("success_step_s", fmt(m_ok.avg_step_s, 1), "365.7 (Fig 3)")
    # failure regime: scale reset latency tails (image pull storms)
    m_bad = run_sim(env_latency_scale=2.5, seed=7, **common)
    b.row("failure_step_s", fmt(m_bad.avg_step_s, 1), "513.3 (Fig 3)")
    b.row("failure_over_success", fmt(m_bad.avg_step_s / m_ok.avg_step_s),
          "1.40 (Fig 3)")
    b.save()
    return b


if __name__ == "__main__":
    run()

"""Fig. 11b (R2 ablation): trajectory-level vs batch-level environment
interaction under injected Gaussian env latency (mu=10s, sigma in 1..10).
Paper: trajectory-level improves 1.23x -> 2.27x as sigma grows."""
from benchmarks.common import Bench, fmt
from repro.core.simrl import run_sim


def run(steps=3):
    b = Bench("traj_vs_batch_fig11b")
    for sigma in (1, 4, 7, 10):
        common = dict(model="qwen3-8b", batch_size=128, num_steps=steps,
                      gen_pools=(("H800", 32),),
                      env_gauss_override=(10.0, float(sigma)),
                      reward_serverless=True, async_weight_sync=False,
                      tasks=("webshop", "frozenlake"))
        m_batch = run_sim(mode="sync", **common)
        m_traj = run_sim(mode="sync_plus", **common)
        ratio = (sum(m_batch.rollout_s) / max(len(m_batch.rollout_s), 1)) / \
            (sum(m_traj.rollout_s) / max(len(m_traj.rollout_s), 1))
        b.row(f"traj_speedup_sigma{sigma}", fmt(ratio),
              "1.23 (sigma=1) -> 2.27 (sigma=10)")
    b.save()
    return b


if __name__ == "__main__":
    run()

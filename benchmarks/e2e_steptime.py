"""Fig. 10a/b: end-to-end step time + throughput across the five systems
(Sync, Sync+, One-off, AReaL, RollArt) at the paper's 32B/batch-512 setup.

Paper bands: RollArt reduces step time 2.05x/1.35x/1.31x vs Sync+/One-off/
AReaL; 2.65-4.58x throughput over Sync. Known deviation (EXPERIMENTS.md):
our AReaL baseline on 96 H800 is not decode-saturated, so the isolated
affinity gain (benchmarks/hw_affinity.py) does not compound here.
"""
from benchmarks.common import Bench, fmt
from repro.core.simrl import run_sim

MODES = [
    ("sync", (("H800", 96),), None, False, False),
    ("sync_plus", (("H800", 96),), None, True, False),
    ("one_off", (("H800", 96),), None, True, False),
    ("areal", (("H800", 96),), None, True, True),
    ("rollart", (("H800", 64), ("H20", 32)),
     {"math": "H20", "game": "H20", "default": "H800"}, True, True),
]


def run(model="qwen3-32b", batch=512, steps=5):
    b = Bench(f"e2e_steptime_{model}")
    res = {}
    for mode, pools, aff, sls, aws in MODES:
        m = run_sim(mode=mode, model=model, batch_size=batch,
                    num_steps=steps, gen_pools=pools, hw_affinity=aff,
                    reward_serverless=sls, async_weight_sync=aws)
        res[mode] = m
        b.row(f"{mode}_step_s", fmt(m.avg_step_s, 1))
        b.row(f"{mode}_tput_tok_s", fmt(m.throughput_tok_s, 0))
    b.row("rollart_vs_syncplus_step",
          fmt(res["sync_plus"].avg_step_s / res["rollart"].avg_step_s),
          "2.05 (Fig 10a)")
    b.row("rollart_vs_oneoff_step",
          fmt(res["one_off"].avg_step_s / res["rollart"].avg_step_s),
          "1.35 (Fig 10a)")
    b.row("rollart_vs_areal_step",
          fmt(res["areal"].avg_step_s / res["rollart"].avg_step_s),
          "1.31 (Fig 10a; see EXPERIMENTS.md deviation)")
    b.row("oneoff_vs_syncplus_step",
          fmt(res["sync_plus"].avg_step_s / res["one_off"].avg_step_s),
          "1.52 (Fig 10b)")
    b.row("syncplus_vs_sync_step",
          fmt(res["sync"].avg_step_s / res["sync_plus"].avg_step_s),
          "1.40-2.40 (Fig 10b)")
    b.row("rollart_vs_sync_tput",
          fmt(res["rollart"].throughput_tok_s
              / res["sync"].throughput_tok_s),
          "2.65-4.58 (Fig 10b)")
    b.save()
    return b


if __name__ == "__main__":
    run()

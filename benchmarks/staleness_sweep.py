"""Fig. 13 (R4 ablation): step time vs asynchronous bound alpha in 1..6.
Paper: larger bounds cut staleness aborts and improve step time by at most
1.22x over alpha=1, plateauing quickly (alpha=1 is the quality default)."""
from benchmarks.common import Bench, fmt
from repro.core.simrl import run_sim


def run(steps=5):
    b = Bench("staleness_fig13")
    for model, batch in (("qwen3-8b", 256), ("qwen3-32b", 512)):
        base = None
        for alpha in (1, 2, 4, 6):
            m = run_sim(mode="rollart", model=model, batch_size=batch,
                        num_steps=steps, alpha=alpha,
                        gen_pools=(("H800", 64), ("H20", 32)),
                        hw_affinity={"math": "H20", "game": "H20",
                                     "default": "H800"},
                        reward_serverless=True, async_weight_sync=True)
            if alpha == 1:
                base = m.avg_step_s
            b.row(f"{model}_alpha{alpha}_step_s", fmt(m.avg_step_s, 1))
            b.row(f"{model}_alpha{alpha}_speedup_vs_a1",
                  fmt(base / m.avg_step_s), "<= 1.22 (Fig 13)")
    b.save()
    return b


if __name__ == "__main__":
    run()
